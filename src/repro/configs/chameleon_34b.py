"""Chameleon-34B: early-fusion mixed-modal decoder [arXiv:2405.09818].

VQ image tokens share the 65536 vocab with text; the vision tokenizer is a
stub — ``input_specs`` supplies precomputed patch/VQ embeddings.  Chameleon
uses QK-norm for training stability (§3.1 of the paper).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
    qk_norm=True,
    rope_theta=10_000.0,
    embeds_input=True,
    citation="arXiv:2405.09818",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
