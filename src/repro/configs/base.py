"""Architecture configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``:

* ``mixer_pattern`` — the repeating sequence of sequence-mixer kinds
  ("attn" | "mamba" | "mlstm" | "slstm"), cycled over layers.  The model is
  compiled as ``lax.scan`` over *super-blocks* of ``len(mixer_pattern)``
  layers (keeps HLO size independent of depth).
* ``moe`` — optional mixture-of-experts FFN replacing the dense FFN on layers
  with ``layer_idx % moe.every_k_layers == moe.offset``.
* ``embeds_input`` — audio/vlm frontends are stubs: training consumes
  precomputed frame/patch embeddings of shape (B, S, d_model).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1
    offset: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads
    moe: MoEConfig | None = None
    mixer_pattern: tuple[str, ...] = ("attn",)
    rope_theta: float = 500_000.0
    qk_norm: bool = False
    sliding_window: int | None = None   # if set, attention is windowed
    embeds_input: bool = False          # audio/vlm stub frontend
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    citation: str = ""
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def __post_init__(self):
        if self.n_layers % len(self.mixer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"super-block size {len(self.mixer_pattern)}")

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.mixer_pattern)

    def ffn_kind(self, layer_idx: int) -> str:
        """FFN kind for absolute layer index: 'moe' | 'dense' | 'none'."""
        if self.moe is not None and \
                layer_idx % self.moe.every_k_layers == self.moe.offset:
            return "moe"
        if self.d_ff > 0:
            return "dense"
        return "none"

    def layer_plan(self) -> list[tuple[str, str]]:
        """[(mixer, ffn)] for one super-block (layer indices 0..sb-1 repeat)."""
        sb = len(self.mixer_pattern)
        if self.moe is not None and sb % self.moe.every_k_layers != 0:
            # ensure the ffn pattern is periodic with the super-block
            raise ValueError(f"{self.name}: moe.every_k_layers must divide "
                             f"super-block size {sb}")
        return [(self.mixer_pattern[i], self.ffn_kind(i)) for i in range(sb)]

    def reduced(self, layers: int = 2, d_model: int = 256, n_heads: int = 4,
                n_kv_heads: int | None = None, d_ff: int | None = None,
                experts: int = 4, vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny sizes (≤2 super-blocks)."""
        sb = len(self.mixer_pattern)
        layers = max(sb, (layers // sb) * sb)
        kv = n_kv_heads or min(n_heads, max(1, self.n_kv_heads * n_heads
                                            // max(self.n_heads, 1)))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(experts, self.moe.num_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=d_model // 2)
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=layers, d_model=d_model,
            n_heads=n_heads, n_kv_heads=kv, head_dim=0,
            d_ff=(d_model * 2 if self.d_ff > 0 else 0) if d_ff is None else d_ff,
            vocab=vocab, moe=moe,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else None,
            dtype="float32")
