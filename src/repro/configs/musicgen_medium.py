"""MusicGen-medium decoder backbone over EnCodec tokens [arXiv:2306.05284].

Audio frontend (EnCodec + codebook interleaving) is a stub per the brief:
``input_specs`` supplies precomputed frame embeddings (B, S, d_model).
24 heads with kv=24 ⇒ full multi-head attention (no GQA grouping).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    mixer_pattern=("attn",),
    rope_theta=10_000.0,
    embeds_input=True,
    citation="arXiv:2306.05284",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
