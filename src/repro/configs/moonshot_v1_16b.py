"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: fine-grained MoE,
64 experts top-6, expert d_ff=1408 (assigned spec; ≈3.9B active params)."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="dense",            # assigned pool tags it dense; MoE FFN per spec
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408),
    rope_theta=50_000.0,
    citation="hf:moonshotai/Moonlight-16B-A3B",
    notes="every layer MoE (Moonlight uses dense layer 0; simplified). "
          "long_500k runs with sliding_window=8192.",
)
