"""xLSTM-125M: alternating mLSTM/sLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own projections (no separate FFN).
1:1 mLSTM:sLSTM interleave (the paper's 125M config mixes both kinds).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    mixer_pattern=("mlstm", "slstm"),
    citation="arXiv:2405.04517",
    notes="long_500k native: recurrent state is O(1) in sequence length.",
)
