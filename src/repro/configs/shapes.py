"""Assigned input shapes.

Decode shapes lower ``serve_step`` (ONE new token against a KV cache of
``seq_len``); train/prefill lower full-sequence programs.  ``long_500k``
requires sub-quadratic attention: SSM/hybrid run natively; pure-attention
archs run with a sliding-window (8192) variant enabled for that shape only
(see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

LONG_CONTEXT_WINDOW = 8_192  # sliding window enabled for long_500k on
                             # pure-attention architectures
