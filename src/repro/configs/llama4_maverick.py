"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family]:
early-fusion VLM, 128 routed experts top-1, MoE interleaved every other layer
(interleave_moe_layer_step=2), d_ff=8192 dense and expert."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  every_k_layers=2, offset=1),
    mixer_pattern=("attn", "attn"),   # super-block of 2: dense FFN, then MoE
    rope_theta=500_000.0,
    embeds_input=True,          # early-fusion image patches via stub frontend
    citation="hf:meta-llama/Llama-4-Scout-17B-16E (family card)",
    notes="long_500k runs with sliding_window=8192 (Llama-4 itself uses "
          "chunked attention for long context).",
)
