"""Phi-4-mini-3.8B [arXiv:2412.08905]: RoPE, SwiGLU, GQA kv=8, 200k vocab,
tied embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    rope_theta=10_000.0,
    tie_embeddings=True,
    citation="arXiv:2412.08905",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
