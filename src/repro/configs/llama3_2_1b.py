"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B]: RoPE-500k, SwiGLU, GQA kv=8,
tied embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    citation="hf:meta-llama/Llama-3.2-1B",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
