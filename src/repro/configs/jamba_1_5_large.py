"""Jamba-1.5-Large: hybrid Mamba+attention 1:7 with MoE [arXiv:2403.19887].

Super-block of 8 layers with the attention layer at index 4 (as in the Jamba
block structure); MoE (16 experts, top-2) on every other layer.  Computed
total ≈ 398B params, matching the model card.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  every_k_layers=2, offset=1),
    mixer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    rope_theta=10_000.0,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    citation="arXiv:2403.19887",
    notes="attention layers attend full-context; Mamba carries long range. "
          "long_500k is native (SSM state is O(1); 9 attn layers' 500k KV "
          "cache at batch=1 is 19.3 GB over the pod).",
)
