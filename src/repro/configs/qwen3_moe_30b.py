"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts top-8, QK-norm,
expert d_ff=768 (fine-grained)."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=0, vocab=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    qk_norm=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen3-30B-A3B",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
