"""InternLM2-1.8B [arXiv:2403.17297]: GQA kv=8, SwiGLU."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544,
    rope_theta=1_000_000.0,
    citation="arXiv:2403.17297",
    notes="long_500k runs with sliding_window=8192 (sub-quadratic carve-out).",
)
