"""Architecture registry: the 10 assigned architectures (+ the paper's own
small models, which live in ``repro.models.small``).

``get(name)`` returns the exact assigned config; ``get(name, shape)`` applies
per-shape adaptations (sliding-window carve-out for long_500k on
pure-attention archs).
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig, MoEConfig
from .shapes import LONG_CONTEXT_WINDOW, SHAPES, InputShape

from . import (chameleon_34b, internlm2_1_8b, jamba_1_5_large, llama3_2_1b,
               llama4_maverick, moonshot_v1_16b, musicgen_medium,
               phi4_mini_3_8b, qwen3_moe_30b, xlstm_125m)

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        musicgen_medium, jamba_1_5_large, xlstm_125m, chameleon_34b,
        llama3_2_1b, internlm2_1_8b, moonshot_v1_16b, phi4_mini_3_8b,
        qwen3_moe_30b, llama4_maverick,
    )
}

ALIASES = {
    "musicgen-medium": "musicgen-medium",
    "jamba-1.5-large-398b": "jamba-1.5-large-398b",
    "xlstm-125m": "xlstm-125m",
    "chameleon-34b": "chameleon-34b",
    "llama3.2-1b": "llama3.2-1b",
    "internlm2-1.8b": "internlm2-1.8b",
    "moonshot-v1-16b-a3b": "moonshot-v1-16b-a3b",
    "phi4-mini-3.8b": "phi4-mini-3.8b",
    "qwen3-moe-30b-a3b": "qwen3-moe-30b-a3b",
    "llama4-maverick-400b-a17b": "llama4-maverick-400b-a17b",
}


def names() -> list[str]:
    return list(REGISTRY)


def get(name: str, shape: str | InputShape | None = None) -> ArchConfig:
    cfg = REGISTRY[ALIASES.get(name, name)]
    if shape is None:
        return cfg
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.name == "long_500k" and "attn" in cfg.mixer_pattern \
            and cfg.family not in ("ssm", "hybrid") \
            and cfg.sliding_window is None:
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


__all__ = ["ArchConfig", "MoEConfig", "InputShape", "SHAPES", "REGISTRY",
           "get", "names", "LONG_CONTEXT_WINDOW"]
