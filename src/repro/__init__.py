"""repro — production-grade JAX framework reproducing and extending
"Asynchronous Wireless Federated Learning with Probabilistic Client Selection"
(Yang, Liu, Chen, Chen, Li; 2023).
"""
__version__ = "1.0.0"
