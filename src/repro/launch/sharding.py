"""Sharding policy: pytree leaf → PartitionSpec.

Baseline (paper-faithful) layout:
  * virtual-client axis (leading K on replica-mode FL state, batch, masks)
    → data-parallel mesh axes ("pod","data")
  * parameters → Megatron-style 1-D tensor parallelism over "model":
    input-side projections shard the output feature dim, output-side
    projections shard the input feature dim (one all-reduce per block);
    experts shard over "model" (expert parallelism); vocab shards embed /
    unembed.
  * masked-DP mode (jamba-398B / llama4-400B) additionally shards every
    parameter's largest remaining dim over "data" (FSDP) so one copy fits.

Every rule is divisibility-guarded; anything unmatched replicates.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


# (regex on keypath, index of dim to shard over "model"); negative = from end
_MODEL_DIM_RULES: list[tuple[str, int]] = [
    (r"\['embed'\]$", 0),                 # [V, d] vocab-sharded
    (r"\['unembed'\]$", -1),              # [d, V]
    (r"\['wq'\]$", -1), (r"\['wk'\]$", -1), (r"\['wv'\]$", -1),
    (r"\['wo'\]$", -2),
    (r"\['ffn'\]\['w1'\]$", -1), (r"\['ffn'\]\['w3'\]$", -1),
    (r"\['ffn'\]\['w2'\]$", -2),
    (r"\['router'\]$", None),             # replicated
    (r"\['in_proj'\]$", -1),
    (r"\['out_proj'\]$", -2),
    (r"\['x_proj'\]$", -2),
    (r"\['dt_proj'\]$", -1),
    (r"\['A_log'\]$", -2), (r"\['dt_bias'\]$", -1), (r"\['D'\]$", -1),
    (r"\['conv_w'\]$", -1), (r"\['conv_b'\]$", -1),
    (r"\['wog'\]$", -1), (r"\['out'\]$", -2),
    (r"\['wi'\]$", None), (r"\['wf'\]$", None),
    (r"\['wz'\]$", -1), (r"\['ri'\]$", None), (r"\['rf'\]$", None),
    (r"\['rz'\]$", None), (r"\['ro'\]$", None),
    (r"norm", None), (r"\['ln1'\]$", None), (r"\['ln2'\]$", None),
]

# MoE expert stacks: [R, E, ., .] — expert-parallel over "model"
_EXPERT_RULE = re.compile(r"\['ffn'\]\['w[123]'\]$")


def param_pspec(path: str, shape: tuple[int, ...], mesh, *,
                stacked_layers: bool, fsdp: bool = False) -> P:
    """PartitionSpec for one parameter leaf.

    path: jax.tree_util.keystr of the leaf inside the *params* pytree
    (no client axis); shape likewise.
    """
    msize = _axis_size(mesh, "model")
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    lead = 1 if (stacked_layers and "blocks" in path) else 0

    model_dim = None
    if re.search(r"\['w[kv]'\]$", path) and ndim - lead == 2:
        # GQA K/V projections: shard only when whole KV heads divide the
        # model axis — splitting a head across shards forces S×S-sized
        # attention reshards (17 GB fp32 ARs per layer at Jamba scale,
        # EXPERIMENTS.md §Perf iteration 3).  KV-head count is not in the
        # path, so use the feature-dim heuristic: replicate unless the flat
        # KV feature dim gives ≥ one whole (≤128-wide) head per shard.
        if shape[-1] % msize == 0 and shape[-1] // msize >= 128:
            model_dim = -1
    elif _EXPERT_RULE.search(path) and ndim - lead >= 3:
        # expert stack [.., E, in, out]: shard experts
        model_dim = lead  # the E dim
    else:
        for pat, dim in _MODEL_DIM_RULES:
            if re.search(pat, path):
                if dim is None:
                    model_dim = None
                else:
                    model_dim = dim if dim < 0 else lead + dim
                break
        else:
            # fallback: largest dim (excluding layer-stack dim) divisible
            cand = [(s, i) for i, s in enumerate(shape)
                    if i >= lead and s % msize == 0 and s >= 2 * msize]
            model_dim = max(cand)[1] if cand else None

    if model_dim is not None:
        md = model_dim % ndim
        if shape[md] % msize == 0 and md >= lead:
            spec[md] = "model"
        else:
            # divisibility guard failed → try fallback largest divisible dim
            cand = [(s, i) for i, s in enumerate(shape)
                    if i >= lead and s % msize == 0 and s >= 2 * msize
                    and spec[i] is None]
            if cand:
                spec[max(cand)[1]] = "model"

    if fsdp and _EXPERT_RULE.search(path):
        # (§Perf iteration 5b: FSDP on embed/unembed turned the logits
        # matmul into fp32 [B,S,V/16]-sized data-axis partial sums — 17 GB
        # per step; vocab-sharded-over-model tables are 67 MB/device and
        # simply replicate over data.)
        # FSDP ("data"-axis weight sharding) is restricted to the MoE expert
        # stacks + embeddings — the only leaves whose replicated copies don't
        # fit.  §Perf iterations 1-4: (1) FSDP on tiny SSM params made GSPMD
        # gather 68 GB fp32 activations per Mamba chunk; (4) FSDP on dense
        # FFN / projection weights turned their contractions into
        # activation-sized partial-sum all-reduces (12.9 GB fp32 per FFN
        # layer at global batch 256×4k) — ~100× the cost of replicating the
        # weight and all-reducing its gradient instead.
        dsize = _axis_size(mesh, "data")
        total_elems = 1
        for s in shape[lead:]:
            total_elems *= s
        if total_elems >= (1 << 24):
            cand = [(s, i) for i, s in enumerate(shape)
                    if i >= lead and spec[i] is None and s % dsize == 0
                    and s >= 8 * dsize]
            if cand:
                spec[max(cand)[1]] = "data"

    return P(*spec)


SMALL_MODEL_ELEMS = int(5e8)


def total_elems(param_shapes: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in
               jax.tree_util.tree_leaves(param_shapes))


def params_shardings(param_shapes: Any, mesh, *, stacked_layers: bool = True,
                     fsdp: bool = False, small_replicate: bool = True) -> Any:
    """Tree of NamedShardings matching a params ShapeDtypeStruct tree.

    Models below SMALL_MODEL_ELEMS replicate entirely — tensor-parallelism
    on a 125M model trades negligible memory for per-layer activation
    all-reduces that dominate its roofline (§Perf iteration 9: xlstm-125m
    was the last collective-bound family).
    """
    if small_replicate and total_elems(param_shapes) < SMALL_MODEL_ELEMS \
            and not fsdp:
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), param_shapes)
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        spec = param_pspec(path, leaf.shape, mesh,
                           stacked_layers=stacked_layers, fsdp=fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def client_stacked_shardings(param_shapes: Any, mesh, *,
                             fsdp: bool = False) -> Any:
    """Shardings for [K, ...] client-stacked params: K over dp axes."""
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    small = sum(int(np.prod(l.shape[1:])) for l in
                jax.tree_util.tree_leaves(param_shapes)) < SMALL_MODEL_ELEMS
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        if small:
            base = P(*([None] * (len(leaf.shape) - 1)))
        else:
            base = param_pspec(path, leaf.shape[1:], mesh,
                               stacked_layers=True, fsdp=fsdp)
        out.append(NamedSharding(mesh, P(dp_spec, *base)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shapes: Any, mesh, *, client_axis: bool,
                    shard_model_batch: bool = False) -> Any:
    """Batch pytree: leading K (client) or B (batch) dim over dp axes."""
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    K = int(np.prod([_axis_size(mesh, a) for a in dp]))

    msize = _axis_size(mesh, "model")

    def one(leaf):
        lead = leaf.shape[0]
        first = dp_spec if lead % K == 0 and lead >= K else None
        rest = [None] * (len(leaf.shape) - 1)
        # small-model DP: also shard the per-client batch dim over "model"
        # (the model axis is otherwise idle when params replicate)
        if shard_model_batch and first is not None and len(leaf.shape) > 1                 and leaf.shape[1] % msize == 0 and leaf.shape[1] >= msize:
            rest[0] = "model"
        return NamedSharding(mesh, P(first, *rest))

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh, batch: int) -> Any:
    """Decode caches: [R, B, ...] leaves — batch over dp if divisible; the
    large per-token dim (KV seq / di) over "model"; for batch=1 the KV seq
    additionally shards over "data"."""
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    K = int(np.prod([_axis_size(mesh, a) for a in dp]))
    msize = _axis_size(mesh, "model")
    dsize = K

    def one(leaf):
        shp = leaf.shape
        spec: list[Any] = [None] * len(shp)
        # leaf layout: [R, B, ...]
        if len(shp) >= 2 and batch % K == 0 and shp[1] == batch and batch >= K:
            spec[1] = dp_spec
            rest_axes = ("model",)
        else:
            rest_axes = ("data", "model") if batch == 1 else ("model",)
        # shard the largest remaining dim that divides
        total = int(np.prod([_axis_size(mesh, a) for a in
                             (rest_axes if isinstance(rest_axes, tuple)
                              else (rest_axes,))]))
        cand = [(s, i) for i, s in enumerate(shp)
                if i >= 2 and spec[i] is None and s % total == 0
                and s >= total]
        if cand:
            i = max(cand)[1]
            spec[i] = rest_axes if len(rest_axes) > 1 else rest_axes[0]
        else:
            # fall back to model-only on the largest dim divisible by msize
            cand = [(s, i) for i, s in enumerate(shp)
                    if i >= 2 and spec[i] is None and s % msize == 0
                    and s >= msize]
            if cand:
                spec[max(cand)[1]] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_shapes)


def client_axis_shardings(tree: Any, mesh, axis: str) -> Any:
    """Shardings for client-stacked data pytrees (e.g. the
    ``DeviceDataStore``'s ``[K, N_max, ...]`` blocks): the leading K axis
    maps onto mesh axis ``axis`` — the same axis the FL state's client
    stack lives on — so per-client shards are co-located with the client
    models that train on them; everything else replicates.  Divisibility-
    guarded like every rule here: a leaf whose leading dim does not divide
    the axis replicates entirely."""
    size = _axis_size(mesh, axis)

    def one(leaf):
        shp = getattr(leaf, "shape", ())
        if len(shp) >= 1 and shp[0] % size == 0 and shp[0] >= size:
            return NamedSharding(mesh, P(axis, *([None] * (len(shp) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, tree)


def ledger_shardings(tree: Any, mesh, axis: str = "k") -> Any:
    """Shardings for the population-sized ``[K]`` ledgers that survive in
    the sparse engine's phase A (cumulative energy, ``last_tx``, anchor
    slots, per-round probability rows).  The participant training program
    is K-independent, so these vectors are the *only* K-sized state left;
    at mega-populations they shard over the client mesh axis exactly like
    the dense store's client axis (same rule set — divisibility-guarded
    leading-dim sharding, scalars replicate)."""
    return client_axis_shardings(tree, mesh, axis)


def replicated(mesh):
    return NamedSharding(mesh, P())
