"""End-to-end training driver.

Two modes:

* paper mode (default): the paper's wireless async-FL experiment — MNIST-like
  data, non-IID shards, MLP, probabilistic client selection + bandwidth
  allocation, energy ledger, checkpointing.

    PYTHONPATH=src python -m repro.launch.train --scheme proposed \
        --rounds 30 --clients 10 --noniid-d 5 --rho 0.05

* arch mode: FL training of a (reduced) assigned architecture on synthetic
  token streams through the same probabilistic-selection round loop —
  the mega-arch path that the dry-run lowers at production shapes.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --rounds 10 --clients 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpoint import save_checkpoint
from ..core import CellConfig, ProblemSpec
from ..core.channel import channel_gains, rate_nats, sample_positions
from ..core.selection import (AgeBasedScheme, GreedyScheme, ProposedOnline,
                              RandomScheme, realize)
from ..data import make_mnist_like, make_token_stream, shard_noniid
from ..fl import SimConfig, run_simulation
from ..fl.distributed import fl_train_step, init_dist_state
from ..models.small import init_mlp, mlp_accuracy, mlp_loss


def paper_mode(args) -> None:
    K = args.clients
    tr, te = make_mnist_like(jax.random.PRNGKey(args.seed),
                             n_train=args.train_examples, n_test=1000)
    clients = shard_noniid(jax.random.PRNGKey(args.seed + 1), tr, K,
                           d=args.noniid_d)
    cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=cell, rho=args.rho, lam=args.lam,
                       num_rounds=args.rounds)
    pos = sample_positions(jax.random.PRNGKey(args.seed + 2), cell)
    h = channel_gains(jax.random.PRNGKey(args.seed + 3), pos, args.rounds).T
    policy = {
        "proposed": lambda: ProposedOnline(spec),
        "random": lambda: RandomScheme(0.1, K),
        "greedy": lambda: GreedyScheme(max(1, K // 10), K),
        "age": lambda: AgeBasedScheme(max(1, K // 10), K),
    }[args.scheme]()
    params = init_mlp(jax.random.PRNGKey(args.seed + 4))
    cfg = SimConfig(rounds=args.rounds, local_iters=args.local_iters,
                    batch_size=args.batch_size, lr=args.lr,
                    eval_every=max(args.rounds // 10, 1), seed=args.seed,
                    max_staleness=args.max_staleness)
    t0 = time.time()
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         policy, h, cell, cfg)
    print(f"[train] scheme={args.scheme} rounds={args.rounds} "
          f"final_acc={res.test_acc[-1]:.4f} "
          f"total_energy_j={res.energy_per_client.sum():.2f} "
          f"({time.time() - t0:.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, res.state.global_params,
                        {"rounds": args.rounds, "scheme": args.scheme,
                         "acc": float(res.test_acc[-1])})
        print(f"[train] checkpoint → {args.ckpt}.npz")


def arch_mode(args) -> None:
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    K = args.clients
    spec_cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=spec_cell, rho=args.rho, num_rounds=args.rounds)
    pos = sample_positions(jax.random.PRNGKey(args.seed), spec_cell)
    h = channel_gains(jax.random.PRNGKey(args.seed + 1), pos, args.rounds).T
    policy = ProposedOnline(spec)

    S, B = args.seq_len, args.per_client_batch
    ds = make_token_stream(jax.random.PRNGKey(args.seed + 2),
                           n_seqs=K * B * 4, vocab=cfg.vocab, seq_len=S)
    toks = ds.x.reshape(-1, K, B, S)
    state = init_dist_state(jax.random.PRNGKey(args.seed + 3), cfg, K)
    key = jax.random.PRNGKey(args.seed + 4)
    for t in range(args.rounds):
        dec = policy.decide(t, h[:, t])
        key, sub = jax.random.split(key)
        mask = realize(sub, dec)
        batch = {"tokens": toks[t % toks.shape[0]]}
        state, metrics = fl_train_step(state, cfg, batch, mask, args.lr)
        R = rate_nats(dec.w, h[:, t], spec_cell.tx_power_w,
                      spec_cell.bandwidth_hz, spec_cell.noise_w_per_hz)
        e = float(jnp.sum(jnp.asarray(mask) * spec_cell.tx_power_w
                          * spec_cell.model_size_nats / jnp.maximum(R, 1e-30)))
        print(f"[train] round {t}: loss={float(metrics['loss']):.4f} "
              f"participants={int(metrics['participants'])} energy_j={e:.3f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, state.global_params,
                        {"arch": cfg.name, "rounds": args.rounds})
        print(f"[train] checkpoint → {args.ckpt}.npz")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scheme", default="proposed",
                    choices=["proposed", "random", "greedy", "age"])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--noniid-d", type=int, default=5)
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--lam", type=float, default=0.01)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=10)
    ap.add_argument("--train-examples", type=int, default=5000)
    ap.add_argument("--max-staleness", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-client-batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    if args.arch:
        arch_mode(args)
    else:
        paper_mode(args)


if __name__ == "__main__":
    main()
