"""Launcher: production mesh, sharding policy, dry-run, train/serve CLIs."""
