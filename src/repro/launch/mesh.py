"""Production mesh definitions (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets XLA_FLAGS before any jax import to fabricate the
512 host devices the multi-pod mesh needs.
"""
from __future__ import annotations

import jax

# --- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16e9             # bytes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (client) axes of a mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_clients(mesh) -> int:
    """Virtual FL clients = product of data-parallel axis sizes."""
    import numpy as np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in dp_axes(mesh)]))
