"""Deprecated alias for :mod:`repro.launch.generate`.

The batched LLM decode demo that used to live here is text generation,
not the FL aggregation front door — the front door is the new
:mod:`repro.serve` subsystem.  ``python -m repro.launch.serve`` keeps
working (it forwards to :func:`repro.launch.generate.main`, emitting the
same ``kind="serve"`` run manifest and ``serve.*`` telemetry), but new
call sites should use ``python -m repro.launch.generate``.
"""
from __future__ import annotations

import warnings

from .generate import main as _generate_main


def main(argv=None):
    warnings.warn(
        "repro.launch.serve is deprecated; the decode demo moved to "
        "repro.launch.generate and the FL front door lives in repro.serve",
        DeprecationWarning,
        stacklevel=2,
    )
    return _generate_main(argv)


if __name__ == "__main__":
    main()
