"""Batched serving driver: prefill a prompt batch, then greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses
    if cfg.embeds_input:
        cfg = dataclasses.replace(cfg, embeds_input=False)  # serve over tokens

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    capacity = args.prompt_len + args.new_tokens

    t0 = time.time()
    logits, caches = T.prefill(params, cfg, tokens=prompts, capacity=capacity)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step = jax.jit(lambda tk, cs: T.decode_step(params, cfg, tk, cs))
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, caches = step(tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] {cfg.name}: batch={args.batch} "
          f"prefill({args.prompt_len} tok) {t_prefill*1e3:.1f} ms, "
          f"decode {args.new_tokens - 1} steps "
          f"{t_decode / max(args.new_tokens - 1, 1) * 1e3:.1f} ms/tok")
    for b in range(min(args.batch, 2)):
        print(f"[serve] sample {b}: {gen[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
