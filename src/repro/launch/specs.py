"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture × input-shape) program — weak-type-correct, shardable, zero
device allocation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..configs.base import ArchConfig
from ..configs.shapes import SHAPES, InputShape
from ..fl.distributed import (DistFLState, fl_train_step,
                              fl_train_step_masked_dp, init_dist_state,
                              mode_for)
from ..models import transformer as T
from . import sharding as SH
from .mesh import dp_axes, num_clients


class ProgramSpec(NamedTuple):
    name: str
    fn: Callable          # positional-args pure function to jit
    args: tuple           # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _model_dtype(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _train_batch_struct(cfg: ArchConfig, K: int, B_per: int, S: int):
    if cfg.embeds_input:
        return {"embeds": _sds((K, B_per, S, cfg.d_model), _model_dtype(cfg)),
                "labels": _sds((K, B_per, S), jnp.int32)}
    return {"tokens": _sds((K, B_per, S), jnp.int32)}


def input_specs(arch: str, shape_name: str, mesh,
                lr: float = 0.01, cfg_override: ArchConfig | None = None,
                mode_override: str | None = None) -> ProgramSpec:
    shape = SHAPES[shape_name]
    cfg = cfg_override or configs.get(arch, shape)
    K = num_clients(mesh)

    if shape.kind == "train":
        mode = mode_override or mode_for(cfg)
        B_per = max(shape.global_batch // K, 1)
        state_struct = jax.eval_shape(
            lambda: init_dist_state(jax.random.PRNGKey(0), cfg, K, mode=mode))
        fsdp = mode == "masked_dp"
        gshard = SH.params_shardings(state_struct.global_params, mesh,
                                     fsdp=fsdp)
        if mode == "replica":
            cshard = SH.client_stacked_shardings(state_struct.client_params,
                                                 mesh)
            state_shard = DistFLState(gshard, cshard, cshard)
        else:
            state_shard = DistFLState(gshard, None, None)
        batch_struct = _train_batch_struct(cfg, K, B_per, shape.seq_len)
        from ..fl.distributed import param_count as _pc
        small = _pc(cfg) < SH.SMALL_MODEL_ELEMS and mode == "replica"
        batch_shard = SH.batch_shardings(batch_struct, mesh, client_axis=True,
                                         shard_model_batch=small)
        mask_struct = _sds((K,), jnp.float32)
        repl = SH.replicated(mesh)
        metrics_shard = {"loss": repl, "participants": repl}

        if mode == "replica":
            # gradient accumulation for big replica-mode archs (§Perf):
            # activation memory ∝ per-client batch / micro_batches
            from ..fl.distributed import param_count
            micro = 8 if param_count(cfg) > 1.5e10 else 1
            while B_per % micro != 0:
                micro //= 2

            def fn(state, batch, mask):
                return fl_train_step.__wrapped__(state, cfg, batch, mask, lr,
                                                 1, micro)
            args = (state_struct, batch_struct, mask_struct)
            in_sh = (state_shard, batch_shard, repl)
        else:
            probs_struct = _sds((K,), jnp.float32)

            def fn(state, batch, mask, probs):
                return fl_train_step_masked_dp.__wrapped__(
                    state, cfg, batch, mask, probs, lr)
            args = (state_struct, batch_struct, mask_struct, probs_struct)
            in_sh = (state_shard, batch_shard, repl, repl)
        return ProgramSpec(
            name=f"{arch}:{shape_name}", fn=fn, args=args, in_shardings=in_sh,
            out_shardings=(state_shard, metrics_shard),
            meta={"cfg": cfg, "mode": mode, "kind": "train", "K": K,
                  "B_per": B_per, "seq": shape.seq_len})

    params_struct = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    # prefill keeps TP even for small models (full-sequence compute amortizes
    # the per-layer ARs; pure-DP replication regressed xlstm prefill 3.6× —
    # §Perf iteration 9 refinement); decode benefits from replication.
    pshard = SH.params_shardings(params_struct, mesh,
                                 small_replicate=shape.kind != "prefill")
    B = shape.global_batch
    repl = SH.replicated(mesh)

    if shape.kind == "prefill":
        S = shape.seq_len
        if cfg.embeds_input:
            batch = {"embeds": _sds((B, S, cfg.d_model), _model_dtype(cfg))}
        else:
            batch = {"tokens": _sds((B, S), jnp.int32)}
        bshard = SH.batch_shardings(batch, mesh, client_axis=False)
        cache_struct = jax.eval_shape(lambda: T.init_caches(cfg, B, S))
        cache_shard = SH.cache_shardings(cache_struct, mesh, B)

        def fn(params, batch):
            logits, caches = T.prefill(params, cfg, capacity=S, **batch)
            # greedy next token — serving returns tokens, not a V-wide tensor
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
        return ProgramSpec(
            name=f"{arch}:{shape_name}", fn=fn, args=(params_struct, batch),
            in_shardings=(pshard, bshard),
            out_shardings=(repl, cache_shard),
            meta={"cfg": cfg, "kind": "prefill", "B": B, "seq": S})

    # decode
    S = shape.seq_len
    cache_struct = jax.eval_shape(lambda: T.init_caches(cfg, B, S))
    # pretend the cache is full (pos = S)
    cache_shard = SH.cache_shardings(cache_struct, mesh, B)
    token = _sds((B, 1), jnp.int32)
    tshard = SH.batch_shardings({"t": token}, mesh, client_axis=False)["t"]

    def fn(params, token, caches):
        logits, caches = T.decode_step(params, cfg, token, caches)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return ProgramSpec(
        name=f"{arch}:{shape_name}", fn=fn,
        args=(params_struct, token, cache_struct),
        in_shardings=(pshard, tshard, cache_shard),
        out_shardings=(tshard, cache_shard),
        meta={"cfg": cfg, "kind": "decode", "B": B, "seq": S})
