"""Batched text generation driver: prefill a prompt batch, then greedy decode.

    PYTHONPATH=src python -m repro.launch.generate --arch qwen3-moe-30b-a3b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16

Formerly ``repro.launch.serve`` — renamed because this is LLM text
generation, not the FL aggregation front door (that now lives in
:mod:`repro.serve`).  The telemetry surface is kept verbatim for
compatibility: request counters (``serve.requests``,
``serve.tokens_generated``), latency spans (``serve.prefill``,
``serve.decode_step``) and the ``kind="serve"`` run manifest written to
``runs.jsonl`` when ``REPRO_OBS_DIR`` is set.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import transformer as T
from ..obs.telemetry import emit_run_manifest, get_telemetry


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses
    if cfg.embeds_input:
        cfg = dataclasses.replace(cfg, embeds_input=False)  # decode over tokens

    tel = get_telemetry()
    tel.inc("serve.requests", args.batch)
    emit_run_manifest("serve", cfg,
                      extra={"arch": args.arch, "batch": args.batch,
                             "prompt_len": args.prompt_len,
                             "new_tokens": args.new_tokens})

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    capacity = args.prompt_len + args.new_tokens

    t0 = time.time()
    with tel.span("serve.prefill"):
        logits, caches = T.prefill(params, cfg, tokens=prompts,
                                   capacity=capacity)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    step = jax.jit(lambda tk, cs: T.decode_step(params, cfg, tk, cs))
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        with tel.span("serve.decode_step"):
            logits, caches = step(tok, caches)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tel.inc("serve.tokens_generated", args.batch * args.new_tokens)

    gen = jnp.concatenate(outs, axis=1)
    print(f"[generate] {cfg.name}: batch={args.batch} "
          f"prefill({args.prompt_len} tok) {t_prefill*1e3:.1f} ms, "
          f"decode {args.new_tokens - 1} steps "
          f"{t_decode / max(args.new_tokens - 1, 1) * 1e3:.1f} ms/tok")
    for b in range(min(args.batch, 2)):
        print(f"[generate] sample {b}: {gen[b, :12].tolist()} ...")
    for name in ("serve.prefill", "serve.decode_step"):
        s = tel.span_stats(name)
        if s:
            print(f"[generate] span {name}: n={s['count']} "
                  f"total={s['total_s']*1e3:.1f} ms "
                  f"max={s['max_s']*1e3:.1f} ms")


if __name__ == "__main__":
    main()
