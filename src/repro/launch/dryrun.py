import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST be the first two lines, before ANY other import (jax locks the
#   device count on first init).  Set here only — smoke tests and benches
#   must keep seeing 1 device.

# Multi-pod dry-run (deliverable e).
# For every (architecture × input shape × mesh) combination:
#   jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
# must succeed; we record memory_analysis(), cost_analysis(), and the
# collective bytes parsed from the post-SPMD optimized HLO into a JSON
# artifact consumed by the roofline analysis (benchmarks/roofline.py).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
#       --shape train_4k [--multi-pod]
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
#       [--out artifacts/dryrun]
import argparse
import json
import re
import time
import traceback

import jax

from .. import configs
from ..configs.shapes import SHAPES
from .mesh import make_production_mesh
from .specs import input_specs

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of_shape(text: str) -> int:
    """Parse 'bf16[8,128]' (or tuple '(f32[..], u32[..])') → total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in post-SPMD optimized HLO.

    Per-device program ⇒ per-device bytes.  ``*-start`` / ``*-done`` pairs
    (async collectives) are counted once via the -start op.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        # '%name = TYPE op(...)' where TYPE is 'bf16[..]' or a tuple '(f32[..], ..)'
        m = re.match(r"^[^=]*=\s*((?:\([^)]*\)|\S+))\s+([a-z-]+)\(", s)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _bytes_of_shape(shape_txt)
            counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _compile_metrics(spec, mesh) -> dict:
    """lower+compile a ProgramSpec; return {flops, bytes, collectives}."""
    with mesh:
        compiled = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                           out_shardings=spec.out_shardings
                           ).lower(*spec.args).compile()
    cost = compiled.cost_analysis() or {}
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes(compiled.as_text())}


def cost_probes(arch: str, shape_name: str, mesh, mode: str) -> dict:
    """1- and 2-super-block unrolled probes (see models/costmode.py):
    total-per-device metric M(R) = M1 + (R−1)·(M2 − M1)."""
    import dataclasses

    from .. import configs as _configs
    from ..models.costmode import cost_probe
    cfg = _configs.get(arch, SHAPES[shape_name])
    sb = len(cfg.mixer_pattern)
    out = {"n_repeats": cfg.n_repeats, "superblock": sb}
    with cost_probe():
        for tag, layers in (("m1", sb), ("m2", 2 * sb)):
            c = dataclasses.replace(cfg, n_layers=layers)
            spec = input_specs(arch, shape_name, mesh, cfg_override=c,
                               mode_override=None if mode == "-" else mode)
            out[tag] = _compile_metrics(spec, mesh)
    r = cfg.n_repeats
    coll1, coll2 = out["m1"]["collectives"], out["m2"]["collectives"]
    out["total"] = {
        "flops": out["m1"]["flops"]
        + (r - 1) * (out["m2"]["flops"] - out["m1"]["flops"]),
        "bytes": out["m1"]["bytes"]
        + (r - 1) * (out["m2"]["bytes"] - out["m1"]["bytes"]),
        "collective_bytes": (coll1["total_bytes"]
                             + (r - 1) * (coll2["total_bytes"]
                                          - coll1["total_bytes"])),
        "collective_bytes_by_kind": {
            k: coll1["bytes"][k] + (r - 1) * (coll2["bytes"][k]
                                              - coll1["bytes"][k])
            for k in coll1["bytes"]},
    }
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True, probe: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    spec = input_specs(arch, shape_name, mesh)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "devices": int(mesh.devices.size), "status": "ok",
                 "kind": spec.meta["kind"],
                 "mode": spec.meta.get("mode", "-")}
    try:
        donate = (0,) if spec.meta["kind"] == "train" else ()
        with mesh:
            jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                             out_shardings=spec.out_shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*spec.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # pre-0.5 jax: list of one dict
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
            "memory_analysis": {
                k: int(getattr(mem, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)},
            "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                              if isinstance(v, (int, float))},
            "collectives": coll,
            "hlo_ops": len(hlo.splitlines()),
        })
        if probe:
            # 1- & 2-super-block unrolled cost probes for exact roofline
            # totals (scan bodies are counted once by HLO cost analysis)
            rec["cost_probe"] = cost_probes(arch, shape_name, mesh,
                                            rec["mode"])
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: OK "
                  f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
            print("  memory_analysis:", rec["memory_analysis"])
            fl = rec["cost_analysis"].get("flops", 0)
            print(f"  cost_analysis: flops/device={fl:.3e} "
                  f"bytes={rec['cost_analysis'].get('bytes accessed', 0):.3e}")
            print("  collectives:", coll["counts"], "→",
                  f"{coll['total_bytes']/1e6:.1f} MB/device")
    except Exception as e:  # noqa: BLE001 — record failures as data
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: FAIL "
                  f"{rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = configs.names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    results = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
            path = os.path.join(args.out, tag.replace("/", "-") + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") == "ok":
                    results.append(prev)
                    print(f"[dryrun] {arch} × {shape}: cached OK")
                    continue
            rec = run_one(arch, shape, args.multi_pod,
                          probe=not args.no_probe)
            results.append(rec)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    ok = sum(r["status"] == "ok" for r in results)
    print(f"[dryrun] {ok}/{len(results)} combinations lowered+compiled")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
