"""Deterministic synthetic datasets.

The container is offline, so MNIST/CIFAR-10 cannot be downloaded.  We generate
procedural stand-ins with the same label structure (10 classes, same example
counts by default) so that the paper's *relative* claims — scheme orderings,
ρ tradeoff shape, fairness — are measurable.  Generators are keyed and fully
deterministic.

``make_mnist_like``  : 784-dim inputs, 10 classes — class-prototype clusters
                       with within-class manifold variation (learnable by the
                       paper's 1×200 MLP, not linearly trivial).
``make_cifar_like``  : 32×32×3 inputs, 10 classes — textured class prototypes.
``make_token_stream``: synthetic LM token streams for the LLM architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Dataset(NamedTuple):
    x: jax.Array       # [N, ...] inputs
    y: jax.Array       # [N] int labels
    num_classes: int


def _cluster_classification(key, n, dim, num_classes, noise, hard_frac=0.35):
    """Class prototypes + per-class low-rank manifolds + noise."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    protos = jax.random.normal(k1, (num_classes, dim)) * 1.2
    rank = max(dim // 16, 4)
    manifolds = jax.random.normal(k2, (num_classes, rank, dim)) * 0.6
    y = jax.random.randint(k3, (n,), 0, num_classes)
    coeff = jax.random.normal(k4, (n, rank))
    base = protos[y] + jnp.einsum("nr,nrd->nd", coeff,
                                  manifolds[y])
    x = base + noise * jax.random.normal(k5, (n, dim))
    return x, y


def make_mnist_like(key: jax.Array, n_train: int = 60_000,
                    n_test: int = 10_000, noise: float = 0.9) -> tuple[Dataset, Dataset]:
    dim, num_classes = 784, 10
    x, y = _cluster_classification(key, n_train + n_test, dim, num_classes,
                                   noise)
    x = jnp.tanh(x)  # bounded like normalized pixels
    tr = Dataset(x[:n_train], y[:n_train], num_classes)
    te = Dataset(x[n_train:], y[n_train:], num_classes)
    return tr, te


def make_cifar_like(key: jax.Array, n_train: int = 50_000,
                    n_test: int = 10_000, noise: float = 1.1) -> tuple[Dataset, Dataset]:
    dim, num_classes = 32 * 32 * 3, 10
    x, y = _cluster_classification(key, n_train + n_test, dim, num_classes,
                                   noise)
    x = jnp.tanh(x).reshape(-1, 32, 32, 3)
    tr = Dataset(x[:n_train], y[:n_train], num_classes)
    te = Dataset(x[n_train:], y[n_train:], num_classes)
    return tr, te


def make_token_stream(key: jax.Array, n_seqs: int, seq_len: int,
                      vocab: int) -> Dataset:
    """Synthetic LM data: per-sequence Markov-ish token chains so that a
    language model has learnable structure (bigram transitions)."""
    k1, k2 = jax.random.split(key)
    # a sparse bigram preference: next ≈ (prev * a + b) mod vocab with noise
    a = int(jax.random.randint(k1, (), 3, 17))
    starts = jax.random.randint(k2, (n_seqs, 1), 0, vocab)

    def step(prev, k):
        noise = jax.random.randint(k, prev.shape, 0, max(vocab // 50, 2))
        nxt = (prev * a + 7 + noise) % vocab
        return nxt, nxt

    keys = jax.random.split(key, seq_len - 1)
    _, rest = jax.lax.scan(step, starts[:, 0], keys)
    toks = jnp.concatenate([starts, rest.T], axis=1)
    return Dataset(toks, jnp.zeros((n_seqs,), jnp.int32), vocab)
