"""Device-resident federated data store.

The scan engine (PR 1) moved the *simulation* on device but left the data
path host-bound: ``stack_round_batches`` materializes a ``[T, K, L, B, ...]``
tensor whose footprint grows linearly in the horizon T (~125 MB at MNIST
scale, T=50 — 5 GB at T=2000).  This module replaces that pre-stack with a
horizon-independent layout:

* :class:`DeviceDataStore` — each client's shard padded to a shared
  ``[K, N_max, ...]`` block with a per-client ``lengths`` mask.  Peak data
  memory is ``K · N_max``, independent of T.
* **on-device per-round sampling** — :func:`round_indices` draws every
  round's minibatch indices from ``fold_in(data_key, t)`` so the stream
  depends only on ``(data seed, t)``; :func:`sample_round` gathers them
  *inside* the jitted scan.  :func:`stack_rounds_reference` evaluates the
  identical stream eagerly into the legacy ``[T, K, L, B, ...]`` layout, so
  the two data paths are bit-identical by construction (the parity tests
  rely on this).
* **jittable partitioners** — :func:`shard_assignment` (the paper's §V-A
  label-shard scheme) and :func:`dirichlet_assignment` (Dirichlet(α)
  heterogeneity) as pure index ops over static shapes: both ``vmap`` over
  the partition key, so a scenario matrix can give every lane its own
  non-IID realization without leaving the device program.
* **streaming fallback** — :class:`StreamingSampler` keeps the padded
  blocks host-side and serves round-chunks through double-buffered
  ``device_put`` prefetch for datasets exceeding the HBM budget;
  :func:`choose_data_path` picks the path from a footprint estimate.

The participation PRNG uses ``fold_in(base_key, t)`` directly; the data
stream folds :data:`DATA_STREAM` into its key first so the two streams never
alias even when built from the same seed.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import Dataset

#: fold_in tag separating the minibatch stream from the participation stream.
DATA_STREAM = 0x0DA7A


class DeviceDataStore(NamedTuple):
    """Padded per-client shards, resident where the simulation runs.

    ``x[k, :lengths[k]]`` are client k's examples; rows at or beyond
    ``lengths[k]`` are zero padding and are never selected by the samplers
    (indices are drawn in ``[0, lengths[k])``).
    """

    x: jax.Array        # [K, N_max, ...] inputs, zero-padded
    y: jax.Array        # [K, N_max] int32 labels, zero-padded
    lengths: jax.Array  # [K] int32 valid example counts

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    @property
    def capacity(self) -> int:
        return self.x.shape[1]

    @property
    def nbytes(self) -> int:
        return int(self.x.size * self.x.dtype.itemsize
                   + self.y.size * self.y.dtype.itemsize
                   + self.lengths.size * 4)


def data_stream_key(seed_or_key) -> jax.Array:
    """Minibatch-stream key for a simulation seed (or an existing key)."""
    key = (jax.random.PRNGKey(seed_or_key)
           if jnp.ndim(seed_or_key) == 0 else seed_or_key)
    return jax.random.fold_in(key, DATA_STREAM)


def _pack_clients(clients: Sequence[Dataset],
                  pad_to: int | None = None):
    """Host-side pad-and-pack shared by the device store and the streaming
    sampler (one implementation ⇒ the two paths stay bit-identical):
    ``(x [K, cap, ...], y [K, cap], counts [K])`` as numpy arrays."""
    counts = [int(np.asarray(c.y).shape[0]) for c in clients]
    if min(counts) == 0:
        raise ValueError("every client shard must be non-empty")
    cap = pad_to or max(counts)
    if cap < max(counts):
        raise ValueError(f"pad_to={cap} < largest shard ({max(counts)})")
    sample = np.asarray(clients[0].x).shape[1:]
    K = len(clients)
    x = np.zeros((K, cap) + sample, np.asarray(clients[0].x).dtype)
    y = np.zeros((K, cap), np.int32)
    for k, c in enumerate(clients):
        x[k, : counts[k]] = np.asarray(c.x)
        y[k, : counts[k]] = np.asarray(c.y)
    return x, y, counts


def from_client_datasets(clients: Sequence[Dataset],
                         pad_to: int | None = None) -> DeviceDataStore:
    """Pack per-client :class:`Dataset` shards into one padded store."""
    x, y, counts = _pack_clients(clients, pad_to)
    return DeviceDataStore(jnp.asarray(x), jnp.asarray(y),
                           jnp.asarray(counts, jnp.int32))


# ---------------------------------------------------------------------------
# per-round sampling (the on-device path's canonical stream)
# ---------------------------------------------------------------------------


def round_indices(data_key: jax.Array, t: jax.Array, lengths: jax.Array,
                  local_iters: int, batch_size: int) -> jax.Array:
    """``[K, L, B]`` example indices for round ``t``, from
    ``fold_in(data_key, t)`` only — uniform over each client's valid rows
    (with replacement), never touching the padding.

    A ``lengths[k] == 0`` client degenerates to sampling padding row 0
    (shape-stable under jit, no way to signal an error from inside a traced
    program) — the host-side constructors (``from_client_datasets``, the
    ``cap=None`` partitioner entries) reject such stores up front; when
    building stores *inside* jit/vmap with an explicit ``cap``, the caller
    owns that check.
    """
    K = lengths.shape[0]
    u = jax.random.uniform(jax.random.fold_in(data_key, t),
                           (K, local_iters, batch_size))
    n = jnp.maximum(lengths, 1).astype(jnp.float32)[:, None, None]
    idx = jnp.floor(u * n).astype(jnp.int32)
    return jnp.minimum(idx, (n - 1.0).astype(jnp.int32))


def gather_round(store: DeviceDataStore,
                 idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather ``([K, L, B, ...], [K, L, B])`` batches for per-client index
    blocks ``idx: [K, L, B]``."""
    xb = jax.vmap(lambda xs, ii: xs[ii])(store.x, idx)
    yb = jax.vmap(lambda ys, ii: ys[ii])(store.y, idx)
    return xb, yb


def sample_round(store: DeviceDataStore, data_key: jax.Array, t: jax.Array,
                 local_iters: int, batch_size: int):
    """One round's stacked client batches, sampled on device (jit/scan-safe)."""
    return gather_round(store, round_indices(data_key, t, store.lengths,
                                             local_iters, batch_size))


def sample_batch(store: DeviceDataStore, data_key: jax.Array, t: jax.Array,
                 batch_size: int):
    """Single-local-iter convenience: ``([K, B, ...], [K, B])``."""
    xb, yb = sample_round(store, data_key, t, 1, batch_size)
    return xb[:, 0], yb[:, 0]


# ---------------------------------------------------------------------------
# per-client stream: indices a single client can draw without touching the
# other K-1 rows (the sparse participation path samples participants only)
# ---------------------------------------------------------------------------


def client_round_indices(data_key: jax.Array, t: jax.Array,
                         client_id: jax.Array, length: jax.Array,
                         local_iters: int, batch_size: int) -> jax.Array:
    """``[L, B]`` example indices for one client at round ``t``.

    The stream is keyed ``fold_in(fold_in(data_key, t), client_id)`` — a pure
    function of ``(data seed, t, k)``, so any *subset* of clients can be
    sampled without materializing draws for the full population (the
    participant-centric sparse path gathers only the transmitting set).
    Like :func:`round_indices`, draws are uniform over ``[0, length)`` with
    replacement and never land in the padding.
    """
    key = jax.random.fold_in(jax.random.fold_in(data_key, t), client_id)
    u = jax.random.uniform(key, (local_iters, batch_size))
    n = jnp.maximum(length, 1).astype(jnp.float32)
    idx = jnp.floor(u * n).astype(jnp.int32)
    return jnp.minimum(idx, (n - 1.0).astype(jnp.int32))


def round_indices_client_stream(data_key: jax.Array, t: jax.Array,
                                lengths: jax.Array, local_iters: int,
                                batch_size: int) -> jax.Array:
    """Dense ``[K, L, B]`` reference of the per-client stream: row ``k`` is
    exactly :func:`client_round_indices` for client ``k`` — gathering a
    subset of rows equals sampling that subset directly (the sparse-path
    parity tests rely on this)."""
    K = lengths.shape[0]
    ks = jnp.arange(K, dtype=jnp.int32)
    return jax.vmap(lambda k, n: client_round_indices(
        data_key, t, k, n, local_iters, batch_size))(ks, lengths)


def sample_round_client_stream(store: DeviceDataStore, data_key: jax.Array,
                               t: jax.Array, local_iters: int,
                               batch_size: int):
    """Dense-engine sampler on the per-client stream (``SimConfig.data_stream
    = "client"``) — the bit-parity reference for the sparse path."""
    return gather_round(store, round_indices_client_stream(
        data_key, t, store.lengths, local_iters, batch_size))


def gather_participant_rounds(store: DeviceDataStore, data_key: jax.Array,
                              part_idx: jax.Array, local_iters: int,
                              batch_size: int):
    """Batches for the transmitting sets of every round, participant-sized.

    ``part_idx: [T, P]`` int32 client ids (padding rows hold ``K``).  Returns
    ``([T, P, L, B, ...], [T, P, L, B])`` — the only contact with the dense
    ``[K, N_max, ...]`` store is a row gather per participant; no
    ``[K, L, B, ...]`` round batch is ever built.  Padding entries gather
    client ``K-1``'s rows (clamped) on a never-used key stream; the sparse
    engine masks them out of the aggregate.
    """
    K = store.num_clients

    def one_round(t, idx_t):
        kc = jnp.clip(idx_t, 0, K - 1)
        lens = store.lengths[kc]
        bidx = jax.vmap(lambda k_raw, n: client_round_indices(
            data_key, t, k_raw, n, local_iters, batch_size))(idx_t, lens)
        xb = jax.vmap(lambda k, ii: store.x[k][ii])(kc, bidx)
        yb = jax.vmap(lambda k, ii: store.y[k][ii])(kc, bidx)
        return xb, yb

    T = part_idx.shape[0]
    ts = jnp.arange(T, dtype=jnp.int32)
    return jax.vmap(one_round)(ts, part_idx)


def stack_rounds_reference(store: DeviceDataStore, data_key: jax.Array,
                           rounds: int, local_iters: int, batch_size: int):
    """Materialize the on-device stream into the legacy ``[T, K, L, B, ...]``
    layout — the parity/benchmark reference for the pre-stack data path.

    Identical keys and gather source ⇒ bit-identical batches to what
    :func:`sample_round` draws inside the scan at each ``t``.
    """
    ts = jnp.arange(rounds, dtype=jnp.int32)
    return jax.jit(jax.vmap(
        lambda t: sample_round(store, data_key, t, local_iters, batch_size)
    ))(ts)


def label_histogram(store: DeviceDataStore, num_classes: int) -> jax.Array:
    """Per-client label counts ``[K, C]`` honoring the length masks."""
    def one(yk, lk):
        valid = jnp.arange(yk.shape[0]) < lk
        return jnp.bincount(jnp.where(valid, yk, num_classes),
                            length=num_classes + 1)[:num_classes]
    return jax.vmap(one)(store.y, store.lengths)


# ---------------------------------------------------------------------------
# jittable non-IID partitioners (pure index ops; vmap over the key for
# per-scenario-lane partitions)
# ---------------------------------------------------------------------------


def assignment_to_store(x: jax.Array, y: jax.Array, assign: jax.Array,
                        num_clients: int, cap: int) -> DeviceDataStore:
    """Turn an example→client assignment ``[N]`` into a padded store.

    Pure index ops with static output shapes (``cap`` rows per client):
    stable-sort by client, then each client reads its contiguous slice.
    Clients owning more than ``cap`` examples are truncated to ``cap``;
    padding rows are zeroed.
    """
    N = y.shape[0]
    order = jnp.argsort(assign)                       # stable
    counts = jnp.bincount(assign, length=num_clients)
    starts = jnp.cumsum(counts) - counts
    pos = starts[:, None] + jnp.arange(cap)[None, :]  # [K, cap]
    lengths = jnp.minimum(counts, cap).astype(jnp.int32)
    valid = jnp.arange(cap)[None, :] < lengths[:, None]
    idx = order[jnp.clip(pos, 0, N - 1)]
    xk = jnp.where(valid.reshape(valid.shape + (1,) * (x.ndim - 1)),
                   x[idx], 0)
    yk = jnp.where(valid, y[idx].astype(jnp.int32), 0)
    return DeviceDataStore(xk, yk, lengths)


def dirichlet_assignment(key: jax.Array, y: jax.Array, num_clients: int,
                         alpha: float, num_classes: int) -> jax.Array:
    """Dirichlet(α) non-IID assignment ``[N] -> client`` (jittable).

    Each client k draws class preferences ``p_k ~ Dirichlet(α·1_C)``; an
    example with label c goes to client k with probability ∝ ``p_k[c]``
    (Gumbel-argmax over clients).  Small α ⇒ each client concentrates on few
    classes; large α ⇒ IID-like.
    """
    k_prop, k_gum = jax.random.split(key)
    props = jax.random.dirichlet(
        k_prop, jnp.full((num_classes,), alpha, jnp.float32),
        shape=(num_clients,))                          # [K, C]
    logits = jnp.log(jnp.maximum(props[:, y], 1e-30))  # [K, N]
    gum = jax.random.gumbel(k_gum, (num_clients, y.shape[0]))
    return jnp.argmax(logits + gum, axis=0).astype(jnp.int32)


def shard_assignment(key: jax.Array, y: jax.Array, num_clients: int, d: int,
                     num_classes: int) -> jax.Array:
    """Paper §V-A label-shard scheme as pure index ops (jittable).

    Splits each class into ``d·K/C`` equal shards and gives every client
    ``d`` shards with distinct labels (for d ≤ C).  Construction: rank
    examples within their class (random tiebreak) → shard-in-class; arrange
    the ``d·K`` shards column-major in a ``[C, d·K/C]`` grid so that ``d``
    consecutive slots always span ``d`` distinct classes; randomize by
    permuting shard columns within each class and permuting client ids.
    """
    S = d * num_clients
    if S % num_classes != 0:
        raise ValueError(f"d*K must be divisible by C={num_classes} "
                         f"(got d={d}, K={num_clients})")
    spc = S // num_classes                             # shards per class
    N = y.shape[0]
    k_tie, k_col, k_cli = jax.random.split(key, 3)

    # rank within class, random order inside each class
    tie = jax.random.uniform(k_tie, (N,))
    order = jnp.argsort(y.astype(jnp.float32) * 2.0 + tie)
    counts = jnp.bincount(y, length=num_classes)
    starts = jnp.cumsum(counts) - counts
    y_sorted = y[order]
    rank = jnp.arange(N) - starts[y_sorted]
    shard_in_class = jnp.minimum(
        (rank * spc) // jnp.maximum(counts[y_sorted], 1), spc - 1)

    # class-local shard → grid column (random per-class permutation)
    colperm = jnp.argsort(jax.random.uniform(k_col, (num_classes, spc)),
                          axis=1)                      # [C, spc]
    col = colperm[y_sorted, shard_in_class]
    slot = col * num_classes + y_sorted                # column-major: slot%C=c
    cperm = jax.random.permutation(k_cli, num_clients)
    assign_sorted = cperm[slot // d].astype(jnp.int32)

    # scatter back to original example order
    return jnp.zeros((N,), jnp.int32).at[order].set(assign_sorted)


def _default_cap(assign: jax.Array, num_clients: int) -> int:
    """Concrete (host-side) capacity: the largest client's example count.
    Also the host entry's chance to reject degenerate partitions — a
    zero-example client would otherwise sample padding row 0 forever (see
    :func:`round_indices`).

    Guarded for huge-K stores: with K ≫ N no partition can leave every
    client non-empty, so the error fires *before* a ``[K]`` bincount is
    materialized (at K ~ 10⁸ the bincount alone is hundreds of MB); the
    capacity readback goes through Python ints, so downstream byte math
    cannot silently overflow a fixed-width accumulator.
    """
    n = int(assign.shape[0])
    if num_clients > n:
        raise ValueError(
            f"partition is degenerate: num_clients={num_clients} exceeds the "
            f"dataset size N={n}, so some client must end up with no "
            "examples — use a larger dataset or fewer clients")
    counts = jnp.bincount(assign, length=num_clients)
    if int(counts.min()) == 0:
        raise ValueError(
            f"partition left client {int(jnp.argmin(counts))} with no "
            "examples — use a larger alpha/dataset or fewer clients")
    cap = int(counts.max())
    if cap <= 0:
        raise ValueError("partition produced a degenerate zero capacity")
    return cap


def dirichlet_store(key: jax.Array, ds: Dataset, num_clients: int,
                    alpha: float, cap: int | None = None) -> DeviceDataStore:
    """Partition a dataset Dirichlet(α)-style straight into a store.

    Host-convenience entry: when ``cap`` is None it is read back from the
    realized counts (not jittable); pass an explicit ``cap`` to stay inside
    jit/vmap.
    """
    assign = dirichlet_assignment(key, ds.y, num_clients, alpha,
                                  ds.num_classes)
    cap = cap if cap is not None else _default_cap(assign, num_clients)
    return assignment_to_store(ds.x, ds.y, assign, num_clients, cap)


def shard_store(key: jax.Array, ds: Dataset, num_clients: int, d: int,
                cap: int | None = None) -> DeviceDataStore:
    """Paper §V-A partition straight into a store (see ``dirichlet_store``
    for the ``cap`` contract)."""
    assign = shard_assignment(key, ds.y, num_clients, d, ds.num_classes)
    cap = cap if cap is not None else _default_cap(assign, num_clients)
    return assignment_to_store(ds.x, ds.y, assign, num_clients, cap)


# ---------------------------------------------------------------------------
# footprint planning: device store vs host streaming
# ---------------------------------------------------------------------------

#: conservative CPU/unknown-backend budget when the runtime reports nothing.
DEFAULT_BUDGET_BYTES = 4 << 30
#: fraction of the budget the data store may claim (model/state/traces need
#: the rest).
STORE_BUDGET_FRACTION = 0.5


def store_bytes(num_clients: int, cap: int, sample_shape: Sequence[int],
                itemsize: int = 4) -> int:
    """Exact padded-store footprint from its shape parameters.

    Matches :attr:`DeviceDataStore.nbytes` term for term: the ``[K, N_max,
    ...]`` inputs, the ``[K, N_max]`` int32 label/mask block, and the ``[K]``
    int32 lengths vector.  All math is Python-int, so a K ~ 10⁹ planning
    query cannot overflow a fixed-width accumulator the way ``np.int64``
    products silently can.
    """
    row = 1
    for s in sample_shape:
        row *= int(s)
    k, cap = int(num_clients), int(cap)
    return k * cap * (row * int(itemsize) + 4) + k * 4


def estimate_store_bytes(clients: Sequence[Dataset]) -> int:
    """Padded-store footprint for a client list, without building it
    (exactly what :func:`from_client_datasets` would allocate, including the
    ``[K, N_max]`` label/mask block and the ``[K]`` lengths vector)."""
    counts = [int(np.asarray(c.y).shape[0]) for c in clients]
    sample = np.asarray(clients[0].x)
    return store_bytes(len(clients), max(counts), sample.shape[1:],
                       sample.dtype.itemsize)


def device_memory_budget() -> int:
    """Usable accelerator memory: ``memory_stats`` when the backend reports
    it, else the ``REPRO_DATA_BUDGET_BYTES`` env override, else 4 GiB."""
    env = os.environ.get("REPRO_DATA_BUDGET_BYTES")
    if env:
        return int(env)
    stats = jax.devices()[0].memory_stats()
    if stats and stats.get("bytes_limit"):
        return int(stats["bytes_limit"])
    return DEFAULT_BUDGET_BYTES


def choose_data_path(clients: Sequence[Dataset],
                     budget_bytes: int | None = None) -> str:
    """``"device"`` when the padded store fits the budget, else ``"stream"``.

    T never enters the estimate — both paths are horizon-independent; only
    the dataset size decides.
    """
    budget = budget_bytes if budget_bytes is not None \
        else device_memory_budget()
    need = estimate_store_bytes(clients)
    return "device" if need <= STORE_BUDGET_FRACTION * budget else "stream"


# ---------------------------------------------------------------------------
# host-streaming fallback: double-buffered round-chunk prefetch
# ---------------------------------------------------------------------------


class StreamingSampler:
    """Serve round-chunks of the canonical stream from host memory.

    Keeps the padded ``[K, N_max, ...]`` blocks as numpy (host) arrays and
    materializes ``[C, K, L, B, ...]`` chunks on demand: indices come from
    the *same* jitted :func:`round_indices` stream as the on-device path
    (bit-identical batches), the gather runs host-side, and the result is
    ``device_put`` ahead of use — the engine overlaps chunk ``i+1``'s
    transfer with chunk ``i``'s compute (double buffering).
    """

    def __init__(self, clients: Sequence[Dataset], data_key: jax.Array,
                 local_iters: int, batch_size: int,
                 pad_to: int | None = None):
        self._x, self._y, counts = _pack_clients(clients, pad_to)
        self.lengths = jnp.asarray(counts, jnp.int32)
        self.data_key = data_key
        self.local_iters = local_iters
        self.batch_size = batch_size
        self._chunk_indices = jax.jit(jax.vmap(
            lambda t: round_indices(data_key, t, self.lengths, local_iters,
                                    batch_size)))

    @property
    def nbytes_host(self) -> int:
        return int(self._x.nbytes + self._y.nbytes)

    def chunk(self, t0: int, t1: int) -> tuple[jax.Array, jax.Array]:
        """Batches for rounds ``[t0, t1)`` as device arrays
        ``([C, K, L, B, ...], [C, K, L, B])`` (the ``device_put`` is the
        prefetch; call it one chunk ahead)."""
        ts = jnp.arange(t0, t1, dtype=jnp.int32)
        idx = np.asarray(self._chunk_indices(ts))      # [C, K, L, B] small
        k_idx = np.arange(self._x.shape[0])[None, :, None, None]
        xb = self._x[k_idx, idx]                       # [C, K, L, B, ...]
        yb = self._y[k_idx, idx]
        return jax.device_put(xb), jax.device_put(yb)
