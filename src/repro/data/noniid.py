"""Non-IID client partitioning (paper §V-A).

"We first divide the dataset into 10 data blocks according to the label, then
further divide each data block into d·K/10 shards, and finally each client is
assigned d shards with different labels."  The non-IID level is controlled by
``d`` — smaller d ⇒ more heterogeneous local datasets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import Dataset


def shard_noniid(key: jax.Array, ds: Dataset, num_clients: int,
                 d: int) -> list[Dataset]:
    """Returns one Dataset per client, each holding ``d`` label-shards with
    distinct labels.  Each client ends with (approximately) N/K examples."""
    C = ds.num_classes
    if (d * num_clients) % C != 0:
        raise ValueError(f"d*K must be divisible by {C} (got d={d}, K={num_clients})")
    shards_per_class = d * num_clients // C

    x = np.asarray(ds.x)
    y = np.asarray(ds.y)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))

    # label -> list of shards (each shard = array of example indices)
    shards: list[tuple[int, np.ndarray]] = []
    for c in range(C):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        for s in np.array_split(idx, shards_per_class):
            shards.append((c, s))

    # greedy assignment: each client takes d shards with distinct labels.
    # When no remaining shard carries a label the client still lacks (e.g.
    # d > C, or an unlucky shuffle near the end), the distinct-label
    # constraint is relaxed for that slot — the client takes the first
    # remaining shard — so every shard is always assigned and no client
    # silently ends up short of d shards (the old code skipped the slot,
    # stranding shards and crashing on np.concatenate([]) for empty
    # clients).
    rng.shuffle(shards)
    clients: list[list[np.ndarray]] = [[] for _ in range(num_clients)]
    client_labels: list[set] = [set() for _ in range(num_clients)]
    # round-robin over clients, pick first shard with an unused label
    remaining = list(shards)
    for _ in range(d):
        for k in range(num_clients):
            pick = next((i for i, (c, _) in enumerate(remaining)
                         if c not in client_labels[k]), 0)
            c, s = remaining.pop(pick)
            clients[k].append(s)
            client_labels[k].add(c)

    out = []
    for k in range(num_clients):
        if not clients[k] or sum(len(s) for s in clients[k]) == 0:
            raise ValueError(
                f"client {k} received no examples: {len(y)} examples over "
                f"{d * num_clients} shards leave some shards empty — use "
                f"fewer clients, smaller d, or more data")
        idx = np.concatenate(clients[k])
        rng.shuffle(idx)
        out.append(Dataset(jnp.asarray(x[idx]), jnp.asarray(y[idx]),
                           ds.num_classes))
    return out


def heterogeneity(clients: list[Dataset]) -> float:
    """Mean pairwise total-variation distance between client label
    distributions — 0 for IID, →1 for disjoint labels."""
    C = clients[0].num_classes
    dists = []
    ps = []
    for ds in clients:
        counts = np.bincount(np.asarray(ds.y), minlength=C).astype(float)
        ps.append(counts / counts.sum())
    for i in range(len(ps)):
        for j in range(i + 1, len(ps)):
            dists.append(0.5 * np.abs(ps[i] - ps[j]).sum())
    return float(np.mean(dists))
