"""Data substrate: synthetic datasets, non-IID partitioners (host greedy +
jittable index-op variants), host batching pipeline, and the device-resident
federated store with on-device per-round sampling and streaming fallback."""
from .device import (DeviceDataStore, StreamingSampler, choose_data_path,
                     data_stream_key, dirichlet_assignment, dirichlet_store,
                     from_client_datasets, gather_round, label_histogram,
                     round_indices, sample_batch, sample_round,
                     shard_assignment, shard_store, stack_rounds_reference)
from .noniid import heterogeneity, shard_noniid
from .pipeline import BatchIterator, client_batches
from .synthetic import Dataset, make_cifar_like, make_mnist_like, make_token_stream

__all__ = ["Dataset", "make_mnist_like", "make_cifar_like", "make_token_stream",
           "shard_noniid", "heterogeneity", "BatchIterator", "client_batches",
           "DeviceDataStore", "StreamingSampler", "choose_data_path",
           "data_stream_key", "dirichlet_assignment", "dirichlet_store",
           "from_client_datasets", "gather_round", "label_histogram",
           "round_indices", "sample_batch", "sample_round",
           "shard_assignment", "shard_store", "stack_rounds_reference"]
