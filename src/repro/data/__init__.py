"""Data substrate: synthetic datasets, the paper's non-IID partitioner,
batching pipeline."""
from .noniid import heterogeneity, shard_noniid
from .pipeline import BatchIterator, client_batches
from .synthetic import Dataset, make_cifar_like, make_mnist_like, make_token_stream

__all__ = ["Dataset", "make_mnist_like", "make_cifar_like", "make_token_stream",
           "shard_noniid", "heterogeneity", "BatchIterator", "client_batches"]
