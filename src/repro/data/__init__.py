"""Data substrate: synthetic datasets, non-IID partitioners (host greedy +
jittable index-op variants), host batching pipeline, and the device-resident
federated store with on-device per-round sampling and streaming fallback."""
from .device import (DeviceDataStore, StreamingSampler, choose_data_path,
                     client_round_indices, data_stream_key,
                     dirichlet_assignment, dirichlet_store,
                     estimate_store_bytes, from_client_datasets,
                     gather_participant_rounds, gather_round, label_histogram,
                     round_indices, round_indices_client_stream, sample_batch,
                     sample_round, sample_round_client_stream,
                     shard_assignment, shard_store, stack_rounds_reference,
                     store_bytes)
from .noniid import heterogeneity, shard_noniid
from .pipeline import BatchIterator, client_batches
from .synthetic import Dataset, make_cifar_like, make_mnist_like, make_token_stream

__all__ = ["Dataset", "make_mnist_like", "make_cifar_like", "make_token_stream",
           "shard_noniid", "heterogeneity", "BatchIterator", "client_batches",
           "DeviceDataStore", "StreamingSampler", "choose_data_path",
           "data_stream_key", "dirichlet_assignment", "dirichlet_store",
           "estimate_store_bytes", "store_bytes", "from_client_datasets",
           "gather_round", "gather_participant_rounds", "label_histogram",
           "round_indices", "client_round_indices",
           "round_indices_client_stream", "sample_batch", "sample_round",
           "sample_round_client_stream", "shard_assignment", "shard_store",
           "stack_rounds_reference"]
