"""Minimal deterministic batching pipeline (device-agnostic, keyed shuffling).

Each client in the FL simulator owns one ``BatchIterator`` over its local
shard; the distributed trainer uses ``client_batches`` to build the stacked
[K, per_client_batch, ...] arrays the vmap-over-clients step consumes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import Dataset


@dataclasses.dataclass
class BatchIterator:
    """Infinite shuffled batches over a dataset (numpy-side, cheap)."""

    ds: Dataset
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._x = np.asarray(self.ds.x)
        self._y = np.asarray(self.ds.y)
        self._order = self._rng.permutation(len(self._y))
        self._pos = 0

    def __next__(self):
        n = len(self._y)
        if self.batch_size >= n:
            return jnp.asarray(self._x), jnp.asarray(self._y)
        if self._pos + self.batch_size > n:
            self._order = self._rng.permutation(n)
            self._pos = 0
        sel = self._order[self._pos: self._pos + self.batch_size]
        self._pos += self.batch_size
        return jnp.asarray(self._x[sel]), jnp.asarray(self._y[sel])

    def __iter__(self):
        return self


def client_batches(iters: list[BatchIterator]) -> tuple[jax.Array, jax.Array]:
    """Stack one batch per client: ([K, B, ...], [K, B])."""
    xs, ys = zip(*(next(it) for it in iters))
    return jnp.stack(xs), jnp.stack(ys)
