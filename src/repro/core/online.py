"""Online variant of Algorithm 1 (paper §IV-D, problem (P1')).

With round-invariant probabilities ``p_{k,t} = p_k`` the solver only needs the
*current* round's channel state: alternate the Lambert-W bandwidth step (31)
with the closed-form probability (46)

    p_k* = clip( (2ρ / (K α_k P_k S T (1−ρ)))^{1/3}, λ, 1 ),

updating (α, β) by the same damped-Newton rule until the residuals vanish.

``rho`` may be passed as a traced array (overriding ``spec.rho``) so the whole
solve can sit under ``vmap`` over the tradeoff coefficient — the scenario-matrix
engine sweeps ρ × seed in one device program.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .algorithm1 import ProblemSpec, solve_p4
from .channel import rate_nats


class OnlineResult(NamedTuple):
    p: jax.Array          # [K]
    w: jax.Array          # [K]
    objective: jax.Array
    residual: jax.Array
    iters: jax.Array


def objective_p1_prime(p, w, h, spec: ProblemSpec, rho=None):
    """Eq. (41)."""
    c = spec.cell
    rho = spec.rho if rho is None else rho
    R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
    conv = rho / spec.K * jnp.sum(p**-2)
    energy = (1 - rho) * spec.T * jnp.sum(
        p * c.tx_power_w * c.model_size_nats / jnp.maximum(R, 1e-30))
    return conv + energy


@partial(jax.jit, static_argnames=("spec", "max_outer", "tol"))
def solve_online(h: jax.Array, spec: ProblemSpec, max_outer: int = 200,
                 tol: float = 1e-10, rho=None) -> OnlineResult:
    """Solve (P1') for a single round's channel gains h: [K].

    ``rho=None`` uses the static ``spec.rho``; a traced scalar makes every
    downstream quantity a function of ρ (vmap-able sweep axis).
    """
    c = spec.cell
    K, T = spec.K, spec.T
    rho = spec.rho if rho is None else rho
    # ρ → 1 sends the energy weight (1−ρ) — and with it every P_k S T (1−ρ)
    # denominator below — to exactly 0, turning the KKT residuals into 0/0.
    # Clamp it to one fp32 ulp: the probabilities still clip to 1 (pure
    # convergence objective) but every intermediate stays finite, so the
    # solver is safe to vmap over a ρ grid that includes the endpoint.
    tiny = jnp.asarray(1e-30, h.dtype)
    PkST1r = (c.tx_power_w * c.model_size_nats * T
              * jnp.maximum(1.0 - rho, 1e-7))
    zeta, eps = 0.1, 0.01  # damping: see algorithm1.solve

    w = jnp.full((K,), 1.0 / K, dtype=h.dtype)
    R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
    p = jnp.clip((2 * rho / jnp.maximum(K * (1.0 / R) * PkST1r, tiny))
                 ** (1 / 3), spec.lam, 1.0)
    alpha, beta = 1.0 / R, p * PkST1r / R

    def res_sq(alpha, beta, p, R):
        psi = alpha * R - 1.0
        kappa = beta * R / jnp.maximum(p * PkST1r, tiny) - 1.0
        return jnp.sum(psi**2) + jnp.sum(kappa**2)

    def outer(carry):
        alpha, beta, p, w, it, _ = carry
        # (46): closed-form probability given α; α_k → 0 (a deep-faded
        # client's 1/R_k) with ρ = 0 is the other 0/0 corner — the max()
        # keeps the ratio finite and the clip lands on λ as the closed
        # form prescribes
        p = jnp.clip((2 * rho / jnp.maximum(K * alpha * PkST1r, tiny))
                     ** (1 / 3), spec.lam, 1.0)
        # (31)/(33): bandwidth given α·β
        w = solve_p4(alpha * beta, h, c)
        R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
        # damped Newton on (α, β) with the (40)-style step rule
        base = res_sq(alpha, beta, p, R)
        ta, tb = 1.0 / R, p * PkST1r / R

        def cand(step):
            return (1 - step) * alpha + step * ta, (1 - step) * beta + step * tb

        def search(carry):
            l, ok, _ = carry
            step = zeta ** l
            a2, b2 = cand(step)
            ok = res_sq(a2, b2, p, R) <= (1 - eps * step) * base
            return l + 1, ok, step

        l, ok, step = jax.lax.while_loop(
            lambda cr: jnp.logical_and(~cr[1], cr[0] <= 30), search,
            (jnp.int32(1), jnp.bool_(False), jnp.asarray(zeta, h.dtype)))
        step = jnp.where(ok, step, zeta)
        alpha, beta = cand(step)
        res = res_sq(alpha, beta, p, R)
        return alpha, beta, p, w, it + 1, res

    def cond(carry):
        *_, it, res = carry
        return jnp.logical_and(it < max_outer, res > tol)

    init = (alpha, beta, p, w, jnp.int32(0), jnp.asarray(jnp.inf, h.dtype))
    alpha, beta, p, w, it, res = jax.lax.while_loop(cond, outer, init)
    return OnlineResult(p=p, w=w,
                        objective=objective_p1_prime(p, w, h, spec, rho=rho),
                        residual=res, iters=it)
