"""Algorithm 1: globally-optimal joint probabilistic client selection and
bandwidth allocation (paper §IV).

Layers:
  inner  (P3)  closed-form BCD for the selection probabilities  (eq. 26)
  inner  (P4)  Lambert-W closed form for bandwidth + dual search on v (eqs. 31/33)
  outer        modified-Newton updates of (α, β, γ)             (eqs. 37-40)

Everything is vectorized over clients/rounds and jit-compiled; shapes are
``p, w, h : [K, T]``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .channel import CellConfig, rate_nats
from .fractional import AuxVars, newton_targets, newton_update, residuals
from .lambertw import lambertw


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """Instance of (P1): channel realizations + scalarization knobs."""

    cell: CellConfig
    rho: float = 0.05            # tradeoff coefficient ρ
    lam: float = 0.01            # fairness floor λ (eq. 14)
    num_rounds: int = 50         # T

    @property
    def T(self) -> int:
        return self.num_rounds

    @property
    def K(self) -> int:
        return self.cell.num_clients


class Algorithm1Result(NamedTuple):
    p: jax.Array          # [K, T] optimal selection probabilities
    w: jax.Array          # [K, T] optimal bandwidth ratios
    objective: jax.Array  # scalar value of (11)
    residual: jax.Array   # final sq-norm of (19)
    iters: jax.Array      # outer iterations used


# ---------------------------------------------------------------------------
# objective (P1), eq. (11)
# ---------------------------------------------------------------------------

def objective_p1(p: jax.Array, w: jax.Array, h: jax.Array,
                 spec: ProblemSpec) -> jax.Array:
    c = spec.cell
    R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
    conv = spec.rho * spec.T**2 / spec.K * jnp.sum(jnp.sum(p, axis=1) ** -2)
    energy = (1.0 - spec.rho) * jnp.sum(
        p * c.tx_power_w * c.model_size_nats / jnp.maximum(R, 1e-30))
    return conv + energy


# ---------------------------------------------------------------------------
# (P3): selection probabilities — closed-form BCD, eq. (26)
# ---------------------------------------------------------------------------

def solve_p3(alpha: jax.Array, spec: ProblemSpec, p0: jax.Array,
             sweeps: int = 60) -> jax.Array:
    """Block-coordinate descent over t for every client k (vectorized over k).

    Stationarity (25) gives the target row-sum  s_{k,t} = (2ρT² / (K α_{k,t}
    P_k S (1−ρ)))^{1/3}; each coordinate update is
    p_{k,t} ← clip(s_{k,t} − Σ_{j≠t} p_{k,j}, λ, 1).
    """
    c = spec.cell
    denom = spec.K * alpha * c.tx_power_w * c.model_size_nats * (1 - spec.rho)
    s = (2.0 * spec.rho * spec.T**2 / denom) ** (1.0 / 3.0)  # [K, T]

    def sweep(p, _):
        def coord(t, p):
            rest = jnp.sum(p, axis=1) - p[:, t]
            new = jnp.clip(s[:, t] - rest, spec.lam, 1.0)
            return p.at[:, t].set(new)
        p = jax.lax.fori_loop(0, spec.T, coord, p)
        return p, None

    p, _ = jax.lax.scan(sweep, p0, None, length=sweeps)
    return p


# ---------------------------------------------------------------------------
# (P4): bandwidth — Lambert-W closed form (31) + dual search on v (33)
# ---------------------------------------------------------------------------

def w_of_v(v: jax.Array, ab: jax.Array, h: jax.Array,
           cell: CellConfig) -> jax.Array:
    """Eq. (31): w*(v) for dual variable v ≥ 0.  ab = α·β  (per client).

    A = 1 + v/(α β W);   w = P h / (W N0 (exp[W0(−e^{−A}) + A] − 1)),
    clipped to [0, 1].  As v→0, A→1 and w→∞ (clips to 1).
    """
    W, N0, P = cell.bandwidth_hz, cell.noise_w_per_hz, cell.tx_power_w
    a = jnp.maximum(ab * W, 1e-30)
    A = 1.0 + v / a
    inner = lambertw(-jnp.exp(-A)) + A
    denom = W * N0 * jnp.expm1(inner)
    w = P * h / jnp.maximum(denom, 1e-30)
    return jnp.clip(w, 0.0, 1.0)


def solve_p4(ab: jax.Array, h: jax.Array, cell: CellConfig,
             iters: int = 60, w_floor: float = 1e-4) -> jax.Array:
    """Per-round bandwidth allocation: find v ≥ 0 s.t. Σ_k w(v) = 1 (or v = 0
    when the unconstrained optimum already fits).  Σ_k w(v) is monotone
    decreasing in v ⇒ bisection (a globally-convergent drop-in for the paper's
    subgradient loop (33); both solve the same 1-D dual).

    ``w_floor``: because every client has p ≥ λ > 0, zero bandwidth ⇒ infinite
    energy, so w* > 0 strictly at any optimum of (P1).  Flooring w stabilizes
    the outer Newton iteration (it bounds α = 1/R) without moving the fixed
    point for floors far below the interior solution.

    ab, h: [K] for a single round.  Returns w*: [K].
    """
    def total(v):
        return jnp.sum(w_of_v(v, ab, h, cell))

    # Exponential search for an upper bracket.
    def grow(carry):
        lo, hi = carry
        return lo, hi * 4.0

    def need_grow(carry):
        _, hi = carry
        return total(hi) > 1.0

    dt = jnp.result_type(ab, h)
    hi0 = jnp.maximum(jnp.max(ab) * cell.bandwidth_hz, 1.0).astype(dt)
    lo, hi = jax.lax.while_loop(need_grow, grow, (jnp.zeros((), dt), hi0))

    def bisect(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        over = total(mid) > 1.0
        return (jnp.where(over, mid, lo), jnp.where(over, hi, mid)), None

    (lo, hi), _ = jax.lax.scan(bisect, (lo, hi), None, length=iters)
    v = 0.5 * (lo + hi)
    w = w_of_v(v, ab, h, cell)
    # complementary slackness: if even v=0 satisfies the constraint, keep it
    w0 = w_of_v(jnp.zeros((), dt), ab, h, cell)
    w = jnp.where(jnp.sum(w0) <= 1.0, w0, w)
    return jnp.clip(w, w_floor, 1.0)


def solve_p4_subgradient(ab, h, cell, iters: int = 400,
                         step0: float = 1.0) -> jax.Array:
    """Paper-faithful subgradient dual loop (eq. 33), kept for parity tests."""
    def body(v, i):
        w = w_of_v(v, ab, h, cell)
        g = 1.0 - jnp.sum(w)
        step = step0 / jnp.sqrt(1.0 + i)
        return jnp.maximum(v - step * g * jnp.maximum(jnp.max(ab), 1e-12)
                           * cell.bandwidth_hz, 0.0), None
    v, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(iters))
    return w_of_v(v, ab, h, cell)


# ---------------------------------------------------------------------------
# Algorithm 1 (outer loop)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("spec", "max_outer", "tol", "zeta"))
def solve(h: jax.Array, spec: ProblemSpec, max_outer: int = 400,
          tol: float = 1e-9, zeta: float = 0.1) -> Algorithm1Result:
    """Run Algorithm 1 on channel gains h: [K, T].

    ζ = 0.1 (the modified-Newton damping base of eqs. 37-40) was selected
    empirically: ζ ≥ 0.3 lets the α = 1/R feedback oscillate on channels with
    >4 orders of magnitude gain spread; ζ = 0.1 contracts to ~1e-10 residual
    in ≤400 outer iterations in fp32 (see EXPERIMENTS.md §Algorithm-1).
    """
    c = spec.cell
    K, T = spec.K, spec.T
    PkS1r = c.tx_power_w * c.model_size_nats * (1.0 - spec.rho)

    # --- initialization: equal bandwidth, mid probabilities -----------------
    dt = h.dtype
    w = jnp.full((K, T), 1.0 / K, dtype=dt)
    p = jnp.full((K, T), min(max(0.5, spec.lam), 1.0), dtype=dt)
    R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
    aux = newton_targets(p, R, PkS1r, spec.rho, T, K)

    def outer(carry):
        aux, p, w, R, it, res = carry
        # inner: (P3) probabilities then (P4) bandwidth per round
        p = solve_p3(aux.alpha, spec, p)
        ab = aux.alpha * aux.beta
        w = jax.vmap(lambda ab_t, h_t: solve_p4(ab_t, h_t, c),
                     in_axes=1, out_axes=1)(ab, h)
        R = rate_nats(w, h, c.tx_power_w, c.bandwidth_hz, c.noise_w_per_hz)
        # outer: damped Newton on (α, β, γ)
        target = newton_targets(p, R, PkS1r, spec.rho, T, K)
        aux, _ = newton_update(aux, target, p, R, PkS1r, spec.rho, T, K,
                               zeta=zeta)
        res = residuals(aux, p, R, PkS1r, spec.rho, T, K).sq_norm
        return aux, p, w, R, it + 1, res

    def cond(carry):
        *_, it, res = carry
        return jnp.logical_and(it < max_outer, res > tol)

    init = (aux, p, w, R, jnp.int32(0), jnp.asarray(jnp.inf, dt))
    aux, p, w, R, it, res = jax.lax.while_loop(cond, outer, init)
    return Algorithm1Result(p=p, w=w, objective=objective_p1(p, w, h, spec),
                            residual=res, iters=it)
