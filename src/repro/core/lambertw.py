"""Principal branch of the Lambert W function, pure JAX.

The paper's bandwidth closed form (eq. 31) evaluates ``W0(-exp(-A))`` with
``A = 1 + v/(αβW) ≥ 1``, i.e. arguments in ``[-1/e, 0)``.  We implement W0 on
its full domain ``[-1/e, ∞)`` with a branch-aware initial guess followed by
Halley iterations (cubic convergence; 12 iterations reach fp64 round-off).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INV_E = 0.36787944117144233  # 1/e

#: arguments this far below −1/e snap to the branch point instead of going
#: NaN.  Callers compute ``−exp(−A)`` with ``A ≥ 1`` in float32 — rounding
#: can land a mathematically-valid argument a few ulp outside the domain,
#: and one NaN here would otherwise poison an entire scan carry.
BRANCH_TOL = 1e-6


def _initial_guess(x: jax.Array) -> jax.Array:
    # Series about the branch point x = -1/e:  W = -1 + p - p²/3 + 11p³/72, p=sqrt(2(ex+1))
    p = jnp.sqrt(jnp.maximum(2.0 * (jnp.e * x + 1.0), 0.0))
    near_branch = -1.0 + p - p * p / 3.0 + 11.0 * p**3 / 72.0
    # Asymptotic for large x: L1 - L2 + L2/L1
    xl = jnp.maximum(x, 2.0)
    l1 = jnp.log(xl)
    l2 = jnp.log(l1)
    asym = l1 - l2 + l2 / l1
    # Padé-ish mid-range guess
    mid = x * (1.0 + 1.4586887 * x) / (1.0 + x * (2.4586887 + 0.43478693 * x))
    guess = jnp.where(x < -0.2, near_branch, jnp.where(x > 2.0, asym, mid))
    return guess


@jax.jit
def lambertw(x: jax.Array) -> jax.Array:
    """W0(x) for x ≥ -1/e (element-wise).  NaN outside the domain, except
    fp noise within ``BRANCH_TOL`` below -1/e, which clamps to the branch
    point (W = -1)."""
    x = jnp.asarray(x, dtype=jnp.result_type(x, jnp.float32))
    x = jnp.where((x < -INV_E) & (x >= -INV_E - BRANCH_TOL), -INV_E, x)
    w = _initial_guess(x)

    def halley(w, _):
        ew = jnp.exp(w)
        f = w * ew - x
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        step = f / jnp.where(jnp.abs(denom) < 1e-300, 1e-300, denom)
        # guard the branch point where wp1 -> 0
        step = jnp.where(jnp.abs(wp1) < 1e-12, 0.0, step)
        return w - step, None

    w, _ = jax.lax.scan(halley, w, None, length=12)
    w = jnp.where(x < -INV_E, jnp.nan, w)
    # exact at the branch point
    w = jnp.where(jnp.abs(x + INV_E) <= 1e-12, -1.0, w)
    return w
