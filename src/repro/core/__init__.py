"""Core contribution of the paper: wireless async-FL scheduling.

Public API:
  channel       — cell/channel model, rates, energies (eqs. 4-5)
  lambertw      — principal-branch Lambert W (pure JAX)
  fractional    — sum-of-ratios transform (Theorem 2 residual system)
  algorithm1    — offline globally-optimal solver (Algorithm 1)
  online        — online variant (P1'), closed form (46)
  selection     — proposed / random / greedy / age-based policies
  convergence   — Lemma 1 / Theorem 1 bounds and metric (10)
"""
from . import algorithm1, channel, convergence, fractional, online, selection
from .algorithm1 import Algorithm1Result, ProblemSpec, objective_p1
from .channel import CellConfig
from .lambertw import lambertw
from .online import OnlineResult, solve_online

__all__ = [
    "algorithm1", "channel", "convergence", "fractional", "online",
    "selection", "Algorithm1Result", "ProblemSpec", "objective_p1",
    "CellConfig", "lambertw", "OnlineResult", "solve_online",
]
