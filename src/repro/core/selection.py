"""Client-selection policies: the paper's proposed scheme, its three §V-A
benchmarks (Random, Greedy top-k gain, Age-based round-robin), and the
related-work baselines for the head-to-head scheme matrix — CSMAAFL-style
channel-aware contention (:func:`csma_policy`, arXiv:2306.01207) and
Hu–Chen–Larsson max-age scheduling (:func:`age_aware_policy`,
arXiv:2212.07356; a *ledger* policy — see :func:`_ledger`).  Their staleness-
aware aggregation counterparts live in :mod:`repro.fl.state`
(``AggregatorConfig``), and :mod:`repro.fl.schemes` pairs the two into named
schemes.

Two layers live here:

1. **Pure jittable policy functions** — the scan engine's native interface.
   A ``PolicyFn`` maps ``(t, h_t, sim_state) -> (probs, w)`` where ``t`` is the
   (possibly traced) round index, ``h_t`` the round's channel gains ``[K]`` and
   ``sim_state`` the engine's :class:`~repro.fl.state.FLState` (or ``None``
   when called outside a simulation, e.g. by :func:`average_participants`).
   Every builder below returns a branch-free array program, so the whole round
   loop can live inside one ``lax.scan`` and be ``vmap``-ed over scenarios.

2. **Legacy ``Policy`` objects** — thin shims kept for existing callers
   (examples, figure scripts, tests).  Each dataclass wraps the corresponding
   pure function as ``.policy_fn`` and keeps the old ``decide`` method.

``realize`` draws the Bernoulli participation for any policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from .algorithm1 import ProblemSpec, solve as solve_offline
from .online import solve_online

#: (t, h_t, sim_state) -> (probs [K], w [K]) — pure, jittable, branch-free.
PolicyFn = Callable[[jax.Array, jax.Array, Optional[Any]],
                    Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass
class RoundDecision:
    probs: jax.Array   # [K] transmit probabilities (deterministic ⇒ 0/1)
    w: jax.Array       # [K] bandwidth ratios allocated by the server


class Policy(Protocol):
    name: str

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision: ...


def realize(key: jax.Array, decision: RoundDecision) -> jax.Array:
    """Bernoulli draw of the participation mask C_t (paper protocol Step 3)."""
    u = jax.random.uniform(key, decision.probs.shape)
    return (u < decision.probs).astype(jnp.float32)


def participants_from_mask(mask: jax.Array, bucket: int):
    """Compact a realized ``[K]`` mask into a padded transmitting index set.

    Returns ``(idx [bucket] int32, valid [bucket] bool, n_tx int32)``:
    ``idx`` holds the transmitting client ids in ascending order, padded with
    the out-of-range sentinel ``K`` (scatters with ``mode="drop"`` discard
    it; gathers clamp it).  Shape-stable under jit — ``bucket`` is static —
    so the sparse engine's round step is compiled per *bucket*, never per K.
    When more than ``bucket`` clients transmit, the overflow is truncated;
    callers must check ``n_tx <= bucket`` (the sparse runner surfaces it as
    a hard error).
    """
    K = mask.shape[0]
    idx = jnp.nonzero(mask > 0, size=bucket, fill_value=K)[0].astype(jnp.int32)
    return idx, idx < K, jnp.sum(mask > 0).astype(jnp.int32)


def realize_participants(key: jax.Array, decision: RoundDecision,
                         bucket: int):
    """Step 3 in index-set form: Bernoulli draw then
    :func:`participants_from_mask` — what a participant-centric server
    actually consumes (it never materializes per-population state beyond the
    ``[K]`` probability vector)."""
    return participants_from_mask(realize(key, decision), bucket)


def participant_bucket(expected: float, cap: int, floor: int = 8) -> int:
    """Pick a padded participant-bucket size for an expected transmitting
    count: mean + 6·sqrt(mean) Poisson-tail headroom, rounded up to a power
    of two, clamped to ``[floor, cap]``.  A small set of bucket sizes keeps
    one compile per bucket across any population sweep."""
    m = max(float(expected), 1.0)
    need = int(m + 6.0 * m ** 0.5 + 4.0)
    b = 1 << max(int(need) - 1, 1).bit_length()
    return max(min(b, int(cap)), min(floor, int(cap)))


# ---------------------------------------------------------------------------
# pure policy functions (engine-native)
# ---------------------------------------------------------------------------


def _state_free(fn: PolicyFn) -> PolicyFn:
    """Tag a policy as independent of the simulation state.

    The scan engine hoists tagged policies out of the sequential round loop:
    all T rounds are solved at once with one ``vmap`` over ``t`` (still inside
    the same device program), which turns e.g. T serial (P1') solves into one
    batched solve.  State-dependent policies (anything reading ``sim_state``)
    must not be tagged and stay inside the scan body.
    """
    fn.state_free = True
    return fn


def _ledger(fn: PolicyFn) -> PolicyFn:
    """Tag a policy as reading only the *ledger* slice of the simulation
    state: ``sim_state.round`` and ``sim_state.last_tx`` (the [K] staleness
    bookkeeping), never the model parameters.

    Ledger policies cannot be hoisted out of the round loop (the ledger is
    part of the scan carry), but they *can* run in the sparse engine's
    phase-A participation scan, which carries exactly those two fields
    (:class:`repro.fl.sparse._DecisionView`) — that is what lets age-aware
    scheduling à la Hu–Chen–Larsson ride the participant-centric path.  A
    ledger policy must tolerate ``sim_state=None`` (callers outside a
    simulation, e.g. :func:`average_participants`, pass the zero-staleness
    view).
    """
    fn.ledger = True
    return fn


def policy_ledger_ok(fn: PolicyFn) -> bool:
    """True when ``fn`` can run from the ledger alone: it is either fully
    state-free or tagged :func:`_ledger`."""
    return getattr(fn, "state_free", False) or getattr(fn, "ledger", False)


def random_policy(p_bar: float, num_clients: int) -> PolicyFn:
    """Uniform probability p̄, equal reserved bandwidth (paper benchmark 1)."""

    def fn(t, h_t, state=None):
        del t, state
        K = num_clients
        probs = jnp.full((K,), p_bar, h_t.dtype)
        w = jnp.full((K,), 1.0 / K, h_t.dtype)
        return probs, w

    return _state_free(fn)


def greedy_policy(k: int, num_clients: int) -> PolicyFn:
    """Top-k clients by instantaneous gain [36], [38]; equal split."""

    def fn(t, h_t, state=None):
        del t, state
        K = num_clients
        idx = jnp.argsort(-h_t)[:k]
        probs = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0)
        w = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0 / k)
        return probs, w

    return _state_free(fn)


def age_policy(k: int, num_clients: int) -> PolicyFn:
    """Round-robin k clients per round [33] (Lemma 3's equal-Δ′ optimum)."""

    def fn(t, h_t, state=None):
        del state
        K = num_clients
        start = (t * k) % K
        idx = (start + jnp.arange(k)) % K
        probs = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0)
        w = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0 / k)
        return probs, w

    return _state_free(fn)


def csma_policy(k: int, num_clients: int, beta: float = 1.0) -> PolicyFn:
    """CSMAAFL-style channel-aware contention (arXiv:2306.01207).

    Clients contend for the uplink with a persistence probability shaped by
    their instantaneous channel: client k's contention share is
    ``c_k = h_k^β / Σ_j h_j^β`` and it transmits with probability
    ``p_k = min(k·c_k, 1)`` — in expectation ~``k`` winners per round, biased
    toward good channels (β = 0 recovers uniform random access, large β
    approaches greedy).  Bandwidth is reserved proportionally to the
    expected share, ``w_k = p_k / Σ p``.  Pair with the ``"csmaafl"``
    aggregator, whose inverse-probability weighting debiases exactly this
    skew.
    """

    def fn(t, h_t, state=None):
        del t, state
        hp = jnp.maximum(h_t.astype(jnp.float32), 1e-30) ** beta
        share = hp / jnp.maximum(jnp.sum(hp), 1e-30)
        probs = jnp.clip(k * share, 0.0, 1.0)
        w = probs / jnp.maximum(jnp.sum(probs), 1e-30)
        return probs.astype(h_t.dtype), w.astype(h_t.dtype)

    return _state_free(fn)


def age_aware_policy(k: int, num_clients: int,
                     gamma: float = 1e-3) -> PolicyFn:
    """Hu–Chen–Larsson age-aware scheduling (arXiv:2212.07356): every round
    the server schedules the ``k`` clients with the largest age of
    information Δτ_k = t − last_tx_k, with a small channel-quality
    tie-break (``gamma`` × the mean-normalized gain — ages are integers, so
    any ``gamma < 1`` breaks ties by channel without ever overriding a
    full round of seniority).  Deterministic probs ∈ {0, 1}, equal
    bandwidth across the scheduled set.

    A *ledger* policy: it reads ``state.round``/``state.last_tx`` only.
    With ``state=None`` (e.g. :func:`average_participants`) ages are taken
    as zero and the schedule degenerates to channel-greedy — the
    cardinality, which is all the participation average sees, is ``k``
    either way.
    """

    def fn(t, h_t, state=None):
        K = num_clients
        if state is None:
            stale = jnp.zeros((K,), jnp.float32)
        else:
            stale = (state.round - state.last_tx).astype(jnp.float32)
        tie = h_t.astype(jnp.float32) \
            / jnp.maximum(jnp.mean(h_t.astype(jnp.float32)), 1e-30)
        score = stale + gamma * jnp.clip(tie, 0.0, 1e3)
        idx = jnp.argsort(-score)[:k]
        probs = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0)
        w = jnp.zeros((K,), h_t.dtype).at[idx].set(1.0 / k)
        return probs, w

    return _ledger(fn)


def policy_blend(policy_fns, sel: jax.Array) -> PolicyFn:
    """One-hot blend of a static policy panel: ``(probs, w) = Σ_i sel_i ·
    policy_i(t, h, state)``.

    ``sel`` is a traced ``[n]`` one-hot vector, so the *scheme* becomes a
    vmap axis: every lane of ``run_scheme_matrix`` evaluates the whole panel
    and keeps its own row (0/1 float blending is exact — 1·x + 0·y ≡ x in
    IEEE arithmetic).  The blend is hoistable only if every member is; it
    can run from the ledger iff every member can.
    """
    fns = list(policy_fns)

    def fn(t, h_t, state=None):
        outs = [p(t, h_t, state) for p in fns]
        probs = sum(sel[i] * o[0] for i, o in enumerate(outs))
        w = sum(sel[i] * o[1] for i, o in enumerate(outs))
        return probs, w

    if all(getattr(p, "state_free", False) for p in fns):
        return _state_free(fn)
    if all(policy_ledger_ok(p) for p in fns):
        return _ledger(fn)
    return fn


def online_policy(spec: ProblemSpec, rho=None) -> PolicyFn:
    """Paper's scheme, online variant (§IV-D): solve (P1') each round.

    ``rho`` may be a traced scalar (vmap sweep axis); ``None`` uses the static
    ``spec.rho``.
    """

    def fn(t, h_t, state=None):
        del t, state
        res = solve_online(h_t, spec, rho=rho)
        return res.p, res.w

    return _state_free(fn)


def offline_policy(spec: ProblemSpec, h_all: jax.Array) -> PolicyFn:
    """Paper's scheme, offline Algorithm 1 pre-solved on the full horizon."""
    res = solve_offline(h_all, spec)
    p_all, w_all = res.p, res.w

    def fn(t, h_t, state=None):
        del h_t, state
        return jnp.take(p_all, t, axis=1), jnp.take(w_all, t, axis=1)

    return _state_free(fn)


def as_policy_fn(policy) -> PolicyFn:
    """Coerce anything policy-shaped into a ``PolicyFn``.

    Accepts (in order): a pure ``PolicyFn``, an object exposing ``.policy_fn``
    (the shims below), or any object with a jax-traceable
    ``decide(t, h_t) -> RoundDecision`` (duck-typed legacy policies).
    """
    if hasattr(policy, "policy_fn"):
        return policy.policy_fn
    if hasattr(policy, "decide"):
        def fn(t, h_t, state=None):
            del state
            dec = policy.decide(t, h_t)
            return dec.probs, dec.w

        return fn
    if callable(policy):
        return policy
    raise TypeError(f"not a policy: {policy!r}")


# ---------------------------------------------------------------------------
# legacy Policy shims (existing callers: examples, fig scripts, tests)
# ---------------------------------------------------------------------------


class _FnPolicy:
    """Mixin: ``decide`` delegates to the wrapped pure ``policy_fn``."""

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        probs, w = self.policy_fn(t, h_t, None)
        return RoundDecision(probs=probs, w=w)


@dataclasses.dataclass
class ProposedOnline(_FnPolicy):
    """Paper's scheme, online variant (§IV-D): solve (P1') each round."""

    spec: ProblemSpec
    name: str = "proposed"

    def __post_init__(self):
        self.policy_fn = online_policy(self.spec)


@dataclasses.dataclass
class ProposedOffline(_FnPolicy):
    """Paper's scheme, offline Algorithm 1 on the full horizon of gains."""

    spec: ProblemSpec
    h_all: jax.Array  # [K, T]
    name: str = "proposed-offline"

    def __post_init__(self):
        self.policy_fn = offline_policy(self.spec, self.h_all)


@dataclasses.dataclass
class RandomScheme(_FnPolicy):
    """All clients transmit with the same probability p̄ (paper benchmark 1).

    Because participation is autonomous, the server must reserve a feasible
    orthogonal allocation up-front: w = 1/K each (Σw = 1 for any realization).
    """

    p_bar: float
    num_clients: int
    name: str = "random"

    def __post_init__(self):
        self.policy_fn = random_policy(self.p_bar, self.num_clients)


@dataclasses.dataclass
class GreedyScheme(_FnPolicy):
    """Top-k clients by instantaneous channel gain [36], [38]; equal split."""

    k: int
    num_clients: int
    name: str = "greedy"

    def __post_init__(self):
        self.policy_fn = greedy_policy(self.k, self.num_clients)


@dataclasses.dataclass
class AgeBasedScheme(_FnPolicy):
    """Round-robin k clients per round [33] — the optimum of Lemma 3's
    equal-Δ′ fairness argument."""

    k: int
    num_clients: int
    name: str = "age"

    def __post_init__(self):
        self.policy_fn = age_policy(self.k, self.num_clients)


@dataclasses.dataclass
class CsmaScheme(_FnPolicy):
    """Channel-aware contention à la CSMAAFL (arXiv:2306.01207)."""

    k: int
    num_clients: int
    beta: float = 1.0
    name: str = "csma"

    def __post_init__(self):
        self.policy_fn = csma_policy(self.k, self.num_clients, self.beta)


@dataclasses.dataclass
class AgeAwareScheme(_FnPolicy):
    """Max-age scheduling à la Hu–Chen–Larsson (arXiv:2212.07356).  The
    legacy ``decide(t, h_t)`` view has no ledger, so it reports the
    zero-staleness schedule; inside a simulation the engines feed the live
    ledger through ``policy_fn``."""

    k: int
    num_clients: int
    gamma: float = 1e-3
    name: str = "age-aware"

    def __post_init__(self):
        self.policy_fn = age_aware_policy(self.k, self.num_clients,
                                          self.gamma)


def average_participants(policy, h_all: jax.Array) -> float:
    """Expected number of transmitting clients per round under a policy —
    used to match k across schemes for fair comparison (paper §V-A).

    One vmapped device program over the horizon (no Python round loop).
    """
    fn = as_policy_fn(policy)
    T = h_all.shape[1]
    ts = jnp.arange(T, dtype=jnp.int32)
    probs = jax.vmap(lambda t, h_t: fn(t, h_t, None)[0])(ts, h_all.T)
    return float(jnp.sum(probs) / T)
