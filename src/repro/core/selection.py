"""Client-selection policies: the paper's proposed scheme and its three
benchmarks (§V-A): Random, Greedy (top-k channel gain), Age-based (round-robin).

A policy maps the current round's channel state to (participation, bandwidth):

  * probabilistic policies return per-client transmit probabilities ``p`` and
    an allocation ``w`` computed *before* the clients' autonomous decisions
    (paper protocol Steps 2-4);
  * deterministic benchmarks return a one-hot mask as the probability vector.

``realize`` draws the Bernoulli participation for any policy.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

from .algorithm1 import ProblemSpec, solve as solve_offline
from .online import solve_online


@dataclasses.dataclass
class RoundDecision:
    probs: jax.Array   # [K] transmit probabilities (deterministic ⇒ 0/1)
    w: jax.Array       # [K] bandwidth ratios allocated by the server


class Policy(Protocol):
    name: str

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision: ...


def realize(key: jax.Array, decision: RoundDecision) -> jax.Array:
    """Bernoulli draw of the participation mask C_t (paper protocol Step 3)."""
    u = jax.random.uniform(key, decision.probs.shape)
    return (u < decision.probs).astype(jnp.float32)


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ProposedOnline:
    """Paper's scheme, online variant (§IV-D): solve (P1') each round."""

    spec: ProblemSpec
    name: str = "proposed"

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        res = solve_online(h_t, self.spec)
        return RoundDecision(probs=res.p, w=res.w)


@dataclasses.dataclass
class ProposedOffline:
    """Paper's scheme, offline Algorithm 1 on the full horizon of gains."""

    spec: ProblemSpec
    h_all: jax.Array  # [K, T]
    name: str = "proposed-offline"

    def __post_init__(self):
        res = solve_offline(self.h_all, self.spec)
        self._p, self._w = res.p, res.w

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        return RoundDecision(probs=self._p[:, t], w=self._w[:, t])


@dataclasses.dataclass
class RandomScheme:
    """All clients transmit with the same probability p̄ (paper benchmark 1).

    Because participation is autonomous, the server must reserve a feasible
    orthogonal allocation up-front: w = 1/K each (Σw = 1 for any realization).
    """

    p_bar: float
    num_clients: int
    name: str = "random"

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        K = self.num_clients
        probs = jnp.full((K,), self.p_bar)
        w = jnp.full((K,), 1.0 / K)
        return RoundDecision(probs=probs, w=w)


@dataclasses.dataclass
class GreedyScheme:
    """Top-k clients by instantaneous channel gain [36], [38]; equal split."""

    k: int
    num_clients: int
    name: str = "greedy"

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        K = self.num_clients
        idx = jnp.argsort(-h_t)[: self.k]
        probs = jnp.zeros((K,)).at[idx].set(1.0)
        w = jnp.zeros((K,)).at[idx].set(1.0 / self.k)
        return RoundDecision(probs=probs, w=w)


@dataclasses.dataclass
class AgeBasedScheme:
    """Round-robin k clients per round [33] — the optimum of Lemma 3's
    equal-Δ′ fairness argument."""

    k: int
    num_clients: int
    name: str = "age"

    def decide(self, t: int, h_t: jax.Array) -> RoundDecision:
        K = self.num_clients
        start = (t * self.k) % K
        idx = (start + jnp.arange(self.k)) % K
        probs = jnp.zeros((K,)).at[idx].set(1.0)
        w = jnp.zeros((K,)).at[idx].set(1.0 / self.k)
        return RoundDecision(probs=probs, w=w)


def average_participants(policy: Policy, h_all: jax.Array) -> float:
    """Expected number of transmitting clients per round under a policy —
    used to match k across schemes for fair comparison (paper §V-A)."""
    T = h_all.shape[1]
    tot = 0.0
    for t in range(T):
        tot += float(jnp.sum(policy.decide(t, h_all[:, t]).probs))
    return tot / T
