"""Sum-of-ratios fractional-programming machinery (paper Theorem 2, eqs. 19/34-40).

Jong's transform turns (P1) into the parameterized subtractive problem (P2) with
auxiliary variables (α, β, γ).  The optimum of (P1) is the joint point where the
inner problem (P2) is solved *and* the residual system (19) vanishes:

    ψ_{k,t} = α_{k,t}·R*_{k,t} − 1
    κ_{k,t} = β_{k,t}·R*_{k,t} − p*_{k,t}·P_k·S·(1−ρ)
    χ_k     = γ_k − ρT²/(K·(Σ_t p*_{k,t})²)

The outer update is the damped (modified-Newton) step (37)-(39) with the Armijo
condition (40).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AuxVars(NamedTuple):
    alpha: jax.Array  # [K, T]
    beta: jax.Array   # [K, T]
    gamma: jax.Array  # [K]


class Residuals(NamedTuple):
    psi: jax.Array    # [K, T]
    kappa: jax.Array  # [K, T]
    chi: jax.Array    # [K]

    @property
    def sq_norm(self) -> jax.Array:
        return (jnp.sum(self.psi**2) + jnp.sum(self.kappa**2)
                + jnp.sum(self.chi**2))


def residuals(aux: AuxVars, p: jax.Array, R: jax.Array, PkS1r: jax.Array,
              rho: float, T: int, K: int) -> Residuals:
    """Evaluate (34)-(36) at the inner solution (p, R) for given aux vars.

    PkS1r: per-client constant ``P_k · S · (1−ρ)`` broadcastable to [K, T].

    We use *relative* residuals (each equation divided by its natural scale) so
    that a single tolerance is meaningful across the wildly different magnitudes
    of α (~1/R), β (~p·P·S/R) and γ (~ρT²/K): the zero set is identical to the
    paper's (19) and the Newton targets are unchanged.
    """
    psi = aux.alpha * R - 1.0
    kappa = aux.beta * R / (p * PkS1r) - 1.0
    sum_p = jnp.sum(p, axis=1)
    chi = aux.gamma * (K * sum_p**2) / (rho * T**2) - 1.0
    return Residuals(psi, kappa, chi)


def newton_targets(p: jax.Array, R: jax.Array, PkS1r: jax.Array,
                   rho: float, T: int, K: int) -> AuxVars:
    """The values that zero each residual exactly (RHS of eqs. 37-39)."""
    alpha_t = 1.0 / R
    beta_t = p * PkS1r / R
    gamma_t = rho * T**2 / (K * jnp.sum(p, axis=1) ** 2)
    return AuxVars(alpha_t, beta_t, gamma_t)


def newton_update(aux: AuxVars, target: AuxVars, p, R, PkS1r, rho, T, K,
                  zeta: float = 0.5, eps: float = 0.01,
                  max_l: int = 30) -> tuple[AuxVars, jax.Array]:
    """Damped Newton step (37)-(39) with step-size rule (40).

    Picks the smallest l ≥ 1 with ζ^l satisfying the Armijo-type decrease; since
    the residuals are affine in (α, β, γ) at fixed (p*, R*), l=1 generally
    accepts, but we implement the search faithfully.
    """
    base = residuals(aux, p, R, PkS1r, rho, T, K).sq_norm

    def cand(step):
        return AuxVars(
            alpha=(1 - step) * aux.alpha + step * target.alpha,
            beta=(1 - step) * aux.beta + step * target.beta,
            gamma=(1 - step) * aux.gamma + step * target.gamma,
        )

    def cond(carry):
        l, accepted = carry[0], carry[1]
        return jnp.logical_and(~accepted, l <= max_l)

    dt = jnp.result_type(p, R)

    def body(carry):
        l, _, _ = carry
        step = jnp.asarray(zeta, dt) ** l
        c = cand(step)
        val = residuals(c, p, R, PkS1r, rho, T, K).sq_norm
        ok = val <= (1.0 - eps * step) * base
        return (l + 1, ok, step)

    l, ok, step = jax.lax.while_loop(cond, body, (jnp.int32(1), jnp.bool_(False),
                                                  jnp.asarray(zeta, dt)))
    step = jnp.where(ok, step, zeta)  # fall back to ζ¹ if search exhausts
    return cand(step), step
