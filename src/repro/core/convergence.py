"""Convergence-rate expressions (paper §III): Lemma 1, eq. (7)/(8), Theorem 1,
and the O(·) metric (10) that Algorithm 1 optimizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lemma1_bound(eta: float, L: float, g_max: float, sigma: float,
                 f_max: float, T: int, delta: jax.Array) -> jax.Array:
    """Eq. (6): bound on (1/T)Σ E‖∇f(x_t)‖² given max intervals Δ_k."""
    K = delta.shape[0]
    return (8.0 * f_max / (eta * T)
            + 92.0 * eta**2 * L**2 * g_max**2 * jnp.sum(delta**2) / K
            + 9.0 * sigma**2)


def expected_delta(p: jax.Array) -> jax.Array:
    """Eq. (7): E[Δ_k] = Σ_t p_{k,t} Π_{τ<t}(1−p_{k,τ}) · t  for p: [K, T].

    (The exact first-communication-time expectation the paper approximates.)
    """
    one_minus = jnp.concatenate(
        [jnp.ones_like(p[:, :1]), jnp.cumprod(1.0 - p[:, :-1], axis=1)], axis=1)
    t = jnp.arange(p.shape[1], dtype=p.dtype)
    return jnp.sum(p * one_minus * t[None, :], axis=1)


def delta_prime(p: jax.Array) -> jax.Array:
    """Eq. (8): periodic approximation Δ'_k = T / Σ_t p_{k,t}."""
    T = p.shape[1]
    return T / jnp.maximum(jnp.sum(p, axis=1), 1e-12)


def theorem1_bound(eta: float, L: float, g_max: float, sigma: float,
                   f_max: float, p: jax.Array) -> jax.Array:
    """Eq. (9): Lemma 1 with Δ_k ← Δ'_k(p)."""
    T = p.shape[1]
    return lemma1_bound(eta, L, g_max, sigma, f_max, T, delta_prime(p))


def convergence_metric(p: jax.Array) -> jax.Array:
    """Eq. (10): (T²/K) Σ_k (Σ_t p_{k,t})^{-2} — the solver's convergence term."""
    K, T = p.shape
    return T**2 / K * jnp.sum(jnp.sum(p, axis=1) ** -2)
