"""Wireless channel model for the FL cell network (paper §II-B, Table II).

All of the paper's closed forms (eqs. 26, 31, 46) are derived with a natural-log
Shannon rate.  We therefore keep *nats* internally: ``rate_nats = w·W·ln(1+SNR)``
and convert the model size ``S`` from bits to nats (``S_nats = S_bits·ln2``) so
that every energy expression ``p·P·S/R`` is numerically identical to the
bits/log2 convention while the paper's formulas hold verbatim.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453


@dataclasses.dataclass(frozen=True)
class CellConfig:
    """Wireless network parameters (paper Table II)."""

    num_clients: int = 10
    cell_radius_m: float = 1000.0
    bandwidth_hz: float = 5e6                  # W
    tx_power_w: float = 0.2                    # P_k (uniform in the paper)
    noise_dbm_per_hz: float = -174.0           # N_0
    model_size_bits: float = 6.37e6            # S (MNIST MLP in the paper)
    min_radius_m: float = 1.0                  # avoid log10(0) at the server

    @property
    def noise_w_per_hz(self) -> float:
        return 10.0 ** (self.noise_dbm_per_hz / 10.0) * 1e-3

    @property
    def model_size_nats(self) -> float:
        return self.model_size_bits * LN2


def path_loss_db(dist_m: jax.Array) -> jax.Array:
    """``128.1 + 37.6 log10(r_km)`` dB (3GPP TR 36.814, paper Table II)."""
    r_km = jnp.maximum(dist_m, 1.0) / 1000.0
    return 128.1 + 37.6 * jnp.log10(r_km)


def path_gain(dist_m: jax.Array) -> jax.Array:
    """Linear channel power gain from the 3GPP path loss."""
    return 10.0 ** (-path_loss_db(dist_m) / 10.0)


def sample_positions(key: jax.Array, cfg: CellConfig,
                     r_min: float | None = None,
                     r_max: float | None = None) -> jax.Array:
    """Uniform positions in an annulus [r_min, r_max] of the cell (meters).

    Uniform *in area*: r = sqrt(u·(r_max²−r_min²)+r_min²).
    """
    r_min = cfg.min_radius_m if r_min is None else r_min
    r_max = cfg.cell_radius_m if r_max is None else r_max
    u = jax.random.uniform(key, (cfg.num_clients,))
    return jnp.sqrt(u * (r_max**2 - r_min**2) + r_min**2)


def sample_fading(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Rayleigh block fading: exponential(1) power gain."""
    return jax.random.exponential(key, shape)


def channel_gains(key: jax.Array, dist_m: jax.Array, num_rounds: int) -> jax.Array:
    """``h_{k,t}`` (num_rounds, K): path gain × i.i.d. Rayleigh fading per round."""
    fading = sample_fading(key, (num_rounds, dist_m.shape[0]))
    return fading * path_gain(dist_m)[None, :]


@partial(jax.jit, static_argnames=())
def rate_nats(w: jax.Array, h: jax.Array, P: jax.Array,
              W: float, N0: float) -> jax.Array:
    """Achievable rate (eq. 4) in nats/s: ``w·W·ln(1 + P·h / (w·W·N0))``.

    Safe at w→0 (rate → 0; the limit of w·ln(1+c/w) is 0⁺).
    """
    w_safe = jnp.maximum(w, 1e-12)
    snr = P * h / (w_safe * W * N0)
    return w_safe * W * jnp.log1p(snr)


def rate_bits(w, h, P, W, N0):
    """Achievable rate in bits/s (Shannon log2)."""
    return rate_nats(w, h, P, W, N0) / LN2


@jax.jit
def tx_energy_j(p: jax.Array, w: jax.Array, h: jax.Array, P: jax.Array,
                W: float, N0: float, S_nats: float) -> jax.Array:
    """Expected per-client transmit energy (eq. 5 summand): ``p·P·S / R``.

    Returns per-client energies; sum for E_t.  Where w==0 the client cannot
    transmit; energy is +inf if p>0 else 0.
    """
    R = rate_nats(w, h, P, W, N0)
    e = p * P * S_nats / jnp.maximum(R, 1e-30)
    return jnp.where(p <= 0.0, 0.0, e)
