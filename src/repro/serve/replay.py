"""Decision log + replay harness: the online path's parity discipline.

Every micro-batch the server admits is appended to a :class:`DecisionLog`
— which clients, at which server version (= the anchor each client trained
from), with which submission sequence number (= the client's minibatch
stream key), staleness, policy probability and energy.  That record is
sufficient to *re-run the whole served session offline* through the scan
engine's participant-shaped training program
(:func:`repro.fl.sparse.build_sparse_train_program`):

* the server's version history *is* phase B's global-model history
  ``hist [T+1, D]`` (version ``v`` = the model after micro-batch ``v-1``),
* each logged micro-batch is one "round" whose anchor slots are the
  recorded ``local_version`` entries,
* each lane's minibatches re-gather from the per-client stream
  ``fold_in(fold_in(data_key, seq), client_id)``
  (:func:`repro.data.device.client_round_indices`) — the same keys the
  live client used, so replayed local SGD consumes identical batches.

The parity contract (asserted in ``tests/test_serve.py`` and the CI
``serve-smoke`` job): integer ledgers — ``last_tx``, per-client transmit
counts, the admitted (client, seq) multiset — reproduce **bit-exactly**;
the energy ledger re-accumulates in identical record order (bit-equal
float adds); the served global model matches the replayed one to the
repo's established float tolerance (vmap lane width differs between the
live single-client step and the bucketed replay, so the last-ulp
guarantee is the same one the dense↔sparse parity tests make).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..data.device import DeviceDataStore, client_round_indices, \
    data_stream_key
from ..fl.faults import GuardConfig
from ..fl.state import AggregatorConfig
from ..optim import Optimizer, sgd

#: decision-log JSON schema tag (bump on incompatible record changes).
LOG_SCHEMA = "repro-serve-log/v1"


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One admitted micro-batch: everything replay needs, nothing else.

    All lists have length ``n`` (the real, unpadded admission count);
    ``bucket`` is the pow2 lane count the server padded to (replay repads
    identically so the aggregation masks match).
    """

    t: int                    # server version the batch applied to
    bucket: int               # padded lane count used on the live path
    ids: tuple                # client ids, admission order
    versions: tuple           # local_version per lane (= anchor slot)
    seqs: tuple               # per-client submission sequence numbers
    stale: tuple              # t - local_version per lane (int)
    probs: tuple              # policy p_{k,t} snapshot at admission (float)
    energy: tuple             # reported upload energy per lane (float, J)

    @property
    def n(self) -> int:
        return len(self.ids)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "BatchRecord":
        return cls(t=int(d["t"]), bucket=int(d["bucket"]),
                   ids=tuple(int(i) for i in d["ids"]),
                   versions=tuple(int(v) for v in d["versions"]),
                   seqs=tuple(int(s) for s in d["seqs"]),
                   stale=tuple(int(s) for s in d["stale"]),
                   probs=tuple(float(p) for p in d["probs"]),
                   energy=tuple(float(e) for e in d["energy"]))


def _opt_dict(obj) -> dict | None:
    return None if obj is None else dataclasses.asdict(obj)


class DecisionLog:
    """Append-only record of a serve session, JSON round-trippable.

    The header pins everything that shapes the replayed program — the
    population size, the data-stream seed, the local-SGD hyper-parameters
    and the guard/aggregator configuration — so a log file alone (plus the
    initial params and the data store) determines the replay bit-for-bit.
    """

    def __init__(self, num_clients: int, seed: int, local_iters: int,
                 batch_size: int, lr: float,
                 guards: GuardConfig | None = None,
                 aggregator: AggregatorConfig | None = None):
        self.header = {
            "schema": LOG_SCHEMA,
            "num_clients": int(num_clients),
            "seed": int(seed),
            "local_iters": int(local_iters),
            "batch_size": int(batch_size),
            "lr": float(lr),
            "guards": _opt_dict(guards),
            "aggregator": _opt_dict(aggregator),
        }
        self.records: list[BatchRecord] = []

    def append(self, rec: BatchRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def guards(self) -> GuardConfig | None:
        g = self.header["guards"]
        return None if g is None else GuardConfig(**g)

    @property
    def aggregator(self) -> AggregatorConfig | None:
        a = self.header["aggregator"]
        return None if a is None else AggregatorConfig(**a)

    def to_dict(self) -> dict:
        return {"header": dict(self.header),
                "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionLog":
        h = d["header"]
        if h.get("schema") != LOG_SCHEMA:
            raise ValueError(f"unknown decision-log schema {h.get('schema')!r}"
                             f" (expected {LOG_SCHEMA})")
        log = cls.__new__(cls)
        log.header = dict(h)
        log.records = [BatchRecord.from_dict(r) for r in d["records"]]
        return log

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @classmethod
    def load(cls, path: str) -> "DecisionLog":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# replay: decision log -> the scan engine's phase-B program
# ---------------------------------------------------------------------------


def gather_logged_rounds(store: DeviceDataStore, data_key: jax.Array,
                         seq_all: jax.Array, id_all: jax.Array,
                         local_iters: int, batch_size: int):
    """Batches for every logged lane: ``([T, P, L, B, ...], [T, P, L, B])``.

    The per-lane key is ``fold_in(fold_in(data_key, seq), client_id)`` —
    the live client's own stream (its submission counter plays the round
    index), unlike :func:`repro.data.device.gather_participant_rounds`
    whose rounds share one ``t``.  Padding lanes (``id == K``) gather
    client ``K-1``'s rows on a never-used key; the aggregate masks them.
    """
    K = store.num_clients

    def one_lane(seq, k_raw):
        kc = jnp.clip(k_raw, 0, K - 1)
        bidx = client_round_indices(data_key, seq, k_raw, store.lengths[kc],
                                    local_iters, batch_size)
        return store.x[kc][bidx], store.y[kc][bidx]

    return jax.vmap(jax.vmap(one_lane))(seq_all, id_all)


class ReplayResult(NamedTuple):
    global_params: Any        # replayed final model (pytree)
    last_tx: np.ndarray       # [K] int32 — version of each client's last admit
    tx_count: np.ndarray      # [K] int64 — admitted uploads per client
    energy: np.ndarray        # [K] f32 — Joules, record-order accumulation
    n_batches: int
    n_uploads: int


def replay_ledgers(log: DecisionLog) -> ReplayResult:
    """Host-side integer/energy ledger reconstruction (no device work).

    Accumulation visits records in log order and lanes in admission order —
    the exact order the live server applied them — so the float energy
    ledger is bit-equal, not merely close.
    """
    K = log.header["num_clients"]
    last_tx = np.zeros((K,), np.int32)
    tx_count = np.zeros((K,), np.int64)
    energy = np.zeros((K,), np.float32)
    n_up = 0
    for rec in log.records:
        ids = np.asarray(rec.ids, np.int64)
        last_tx[ids] = rec.t
        np.add.at(tx_count, ids, 1)
        np.add.at(energy, ids, np.asarray(rec.energy, np.float32))
        n_up += rec.n
    return ReplayResult(global_params=None, last_tx=last_tx,
                        tx_count=tx_count, energy=energy,
                        n_batches=len(log.records), n_uploads=n_up)


def replay_session(log: DecisionLog, store: DeviceDataStore, params: Any,
                   loss_fn: Callable, acc_fn: Callable,
                   opt: Optimizer | None = None,
                   test_x=None, test_y=None) -> ReplayResult:
    """Re-run a served session offline through the scan engine.

    Builds the participant-shaped training program
    (:func:`repro.fl.sparse.build_sparse_train_program`) with one scan step
    per logged micro-batch: ``slot_all`` = the recorded local versions,
    batches re-gathered from each lane's own ``(seq, client_id)`` stream.
    Returns the replayed final model plus the host-reconstructed ledgers.
    """
    import dataclasses as _dc

    from ..fl.engine import SimConfig
    from ..fl.sparse import build_sparse_train_program

    if len(log.records) == 0:
        led = replay_ledgers(log)
        return led._replace(global_params=params)
    h = log.header
    K = h["num_clients"]
    T = len(log.records)
    P = max(r.bucket for r in log.records)
    L, B = h["local_iters"], h["batch_size"]

    ids = np.full((T, P), K, np.int32)          # sentinel-K padding
    seqs = np.zeros((T, P), np.int32)
    slots = np.zeros((T, P), np.int32)
    stale = np.zeros((T, P), np.int32)
    probs = np.zeros((T, P), np.float32)
    valid = np.zeros((T, P), bool)
    for i, rec in enumerate(log.records):
        n = rec.n
        ids[i, :n] = rec.ids
        seqs[i, :n] = rec.seqs
        slots[i, :n] = rec.versions
        stale[i, :n] = rec.stale
        probs[i, :n] = rec.probs
        valid[i, :n] = True

    data_key = data_stream_key(h["seed"])
    xb, yb = jax.jit(lambda s, k: gather_logged_rounds(
        store, data_key, s, k, L, B))(jnp.asarray(seqs), jnp.asarray(ids))
    if test_x is None:      # evals are incidental here — any valid batch
        test_x, test_y = store.x[0, :1], store.y[0, :1]
    cfg = SimConfig(rounds=T, local_iters=L, batch_size=B, lr=h["lr"],
                    eval_every=max(T, 1), local_mode="participants",
                    data_stream="client", guards=log.guards,
                    aggregator=log.aggregator)
    program = jax.jit(build_sparse_train_program(
        loss_fn, acc_fn, opt or sgd(h["lr"]), cfg))
    out = program(params, xb, yb, jnp.asarray(valid), jnp.asarray(slots),
                  jnp.int32(K), test_x, test_y,
                  delivered_all=jnp.asarray(valid),
                  stale_all=jnp.asarray(stale),
                  probs_all=jnp.asarray(probs))
    led = replay_ledgers(log)
    return led._replace(global_params=jax.block_until_ready(out[0]))


def verify_replay(server, store: DeviceDataStore, params: Any,
                  loss_fn: Callable, acc_fn: Callable,
                  opt: Optimizer | None = None,
                  rtol: float = 1e-4, atol: float = 1e-5) -> dict:
    """Assert the replay-parity contract against a (closed) server.

    Integer ledgers must match bit-exactly, the energy ledger bit-equal
    (identical accumulation order), the model to ``(rtol, atol)`` — the
    repo's established golden-trace tolerance.  Returns a report dict
    (max abs model error, batch/upload counts); raises ``AssertionError``
    with the first violated invariant otherwise.
    """
    res = replay_session(server.log, store, params, loss_fn, acc_fn, opt=opt)
    snap = server.ledger_snapshot()
    np.testing.assert_array_equal(res.last_tx, snap["last_tx"],
                                  err_msg="replay last_tx mismatch")
    np.testing.assert_array_equal(res.tx_count, snap["tx_count"],
                                  err_msg="replay tx_count mismatch")
    np.testing.assert_array_equal(res.energy, snap["energy"],
                                  err_msg="replay energy ledger mismatch")
    served = jax.tree_util.tree_leaves(server.global_params())
    replayed = jax.tree_util.tree_leaves(res.global_params)
    max_err = 0.0
    for s, r in zip(served, replayed):
        s, r = np.asarray(s), np.asarray(r)
        np.testing.assert_allclose(r, s, rtol=rtol, atol=atol,
                                   err_msg="replayed global model diverged")
        if s.size:
            max_err = max(max_err, float(np.max(np.abs(r - s))))
    return {"n_batches": res.n_batches, "n_uploads": res.n_uploads,
            "model_max_abs_err": max_err, "ok": True}
