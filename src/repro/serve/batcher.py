"""Micro-batcher: coalesce async uploads into the jitted aggregation step.

Two pieces:

* :func:`build_apply_fn` — the device side.  Pads a Python list of client
  delta pytrees to a pow2 *bucket* (:func:`pick_bucket`, mirroring the
  sparse engine's ``participant_bucket`` discipline: a handful of bucket
  shapes ⇒ a handful of compiles, whatever the traffic level) and drives
  the **same** participant-subset aggregation family as the scan engine's
  phase B — ``scheme_subset_aggregate`` / ``guarded_subset_aggregate`` /
  ``subset_aggregate``, in the same precedence order, with the population
  size as the 1/K divisor.  Replay parity depends on this: an offline
  re-run through ``build_sparse_train_program`` hits the identical
  aggregation code on identically-padded lanes.
* :class:`MicroBatcher` — the host side.  A daemon thread parked on the
  server's condition variable; it flushes when a full ``max_batch`` is
  pending or the oldest pending update has waited ``flush_interval_s``
  (the latency bound), in the maxtext ``offline_inference`` idiom of
  background threads feeding batched device calls.
"""
from __future__ import annotations

import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from ..fl.state import (guarded_subset_aggregate, scheme_subset_aggregate,
                        subset_aggregate)


def pick_bucket(n: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power of two ≥ max(n, min_bucket), clamped to max_batch."""
    need = max(int(n), int(min_bucket), 1)
    b = 1 << (need - 1).bit_length()
    return min(b, int(max_batch))


#: (guards, aggregator, num_clients) -> jitted _agg.  Sharing the inner jit
#: across server instances keeps the per-bucket compile cache warm between
#: sessions (a fresh closure per server would recompile every bucket).
_AGG_CACHE: dict = {}


def build_apply_fn(guards, aggregator, num_clients: int):
    """``(global, deltas: list[pytree], bucket, stale [n], probs [n]) ->
    global'`` — one jit specialization per bucket shape (jax retraces on
    the padded shapes; ``pick_bucket`` keeps that set small)."""
    ap = aggregator.params() if aggregator is not None else None
    kf = jnp.int32(num_clients)

    cache_key = (guards, aggregator, int(num_clients))
    cached = _AGG_CACHE.get(cache_key)

    if cached is not None:
        _agg = cached
    else:
        @jax.jit
        def _agg(g, deltas_p, valid, stale_p, probs_p):
            # precedence mirrors fl/sparse.build_sparse_train_program exactly
            if aggregator is not None:
                return scheme_subset_aggregate(g, deltas_p, valid, kf,
                                               stale_p, probs_p, ap,
                                               guards=guards)
            if guards is not None and guards.active:
                return guarded_subset_aggregate(g, deltas_p, valid, kf,
                                                stale_p, guards)
            return subset_aggregate(g, deltas_p, valid, kf)
        _AGG_CACHE[cache_key] = _agg

    def apply(g: Any, deltas: list, bucket: int, stale: jax.Array,
              probs: jax.Array):
        n = len(deltas)

        def stack(*leaves):
            s = jnp.stack(leaves)
            if bucket > n:
                pad = jnp.zeros((bucket - n,) + s.shape[1:], s.dtype)
                s = jnp.concatenate([s, pad], axis=0)
            return s

        deltas_p = jax.tree_util.tree_map(stack, *deltas)
        valid = jnp.arange(bucket) < n
        stale_p = jnp.zeros((bucket,), jnp.int32).at[:n].set(stale)
        probs_p = jnp.zeros((bucket,), jnp.float32).at[:n].set(probs)
        return _agg(g, deltas_p, valid, stale_p, probs_p)

    return apply


class MicroBatcher(threading.Thread):
    """Background flush loop.  Holds the server's condition variable only to
    *decide* when to flush; the flush itself (device work) runs unlocked
    through :meth:`AggregationServer.flush`.  A device-side exception is
    recorded on :attr:`error` and stops the loop (the server's ``close``
    drain will re-raise it to the caller)."""

    def __init__(self, server):
        super().__init__(daemon=True, name="repro-serve-batcher")
        self._srv = server
        self._halt = threading.Event()
        self.error: BaseException | None = None

    def run(self) -> None:
        srv = self._srv
        cfg = srv.cfg
        while not self._halt.is_set():
            with srv._cv:
                while (not srv._pending and not self._halt.is_set()
                        and not srv._closed):
                    srv._cv.wait(timeout=0.05)
                if self._halt.is_set():
                    return
                if not srv._pending:       # closed and drained
                    return
                if not srv._closed and len(srv._pending) < cfg.max_batch:
                    oldest = min(p.ticket.arrival_s
                                 for p in srv._pending.values())
                    wait_for = (cfg.flush_interval_s
                                - (time.perf_counter() - oldest))
                    if wait_for > 0:
                        srv._cv.wait(timeout=wait_for)
                        continue
            try:
                srv.flush()
            except BaseException as e:     # pragma: no cover - defensive
                self.error = e
                return

    def stop(self, timeout: float = 10.0) -> None:
        self._halt.set()
        with self._srv._cv:
            self._srv._cv.notify_all()
        self.join(timeout=timeout)
