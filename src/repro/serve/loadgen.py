"""Load generator: thousands of probabilistically-transmitting clients.

Emulates the paper's client population against a live
:class:`~repro.serve.server.AggregationServer` without one OS thread per
client: a small worker pool draws *which* client acts next from a
heterogeneous activity distribution (lognormal weights — a few chatty
clients, a long quiet tail), pulls the current global + the served
``p_{k,t}``, gates on the client's own Bernoulli draw (the paper's
autonomous participation), runs the real local-SGD step on the client's
own minibatch stream, and submits the delta.  Every submission keys its
minibatches by the client's private sequence counter — exactly what the
decision log records, so a load-generated session replays bit-for-bit
through :func:`repro.serve.replay.replay_session`.

The report (and ``benchmarks/bench_serve.py`` → ``BENCH_serve.json``)
measures sustained admitted uploads/s, admission-latency percentiles and
micro-batch occupancy from the server's telemetry.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..data.device import DeviceDataStore, client_round_indices, \
    data_stream_key
from ..obs.telemetry import emit_run_manifest, get_telemetry
from ..optim import Optimizer, sgd


@dataclasses.dataclass(frozen=True)
class LoadGenConfig:
    """``uploads`` is the admitted-upload target (the run also stops at
    ``timeout_s``).  ``rate_sigma`` spreads client activity lognormally
    (0 = uniform).  ``pace_s`` adds exponential think-time per submission
    (0 = max-throughput mode).  ``respect_probs`` gates each upload on the
    served ``p_{k,t}``; switch it off to stress raw ingest throughput."""

    uploads: int = 500
    workers: int = 4
    seed: int = 0
    rate_sigma: float = 1.0
    pace_s: float = 0.0
    respect_probs: bool = True
    timeout_s: float = 120.0
    ticket_wait_s: float = 30.0


def toy_world(num_clients: int, dim: int = 16, classes: int = 10,
              n_per: int = 8, seed: int = 0):
    """A tiny linear-softmax world sized for CPU load tests: returns
    ``(params, store, loss_fn, acc_fn)``.  Clients get gaussian clusters
    per label so the model has something real to learn."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, dim)).astype(np.float32)
    y = rng.integers(0, classes, (num_clients, n_per))
    x = centers[y] + 0.5 * rng.normal(
        size=(num_clients, n_per, dim)).astype(np.float32)
    store = DeviceDataStore(jnp.asarray(x, jnp.float32),
                            jnp.asarray(y, jnp.int32),
                            jnp.full((num_clients,), n_per, jnp.int32))
    params = {"w": jnp.zeros((dim, classes), jnp.float32),
              "b": jnp.zeros((classes,), jnp.float32)}

    def loss_fn(p, xb, yb):
        logits = xb @ p["w"] + p["b"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - ll)

    def acc_fn(p, xb, yb):
        return jnp.mean(jnp.argmax(xb @ p["w"] + p["b"], axis=-1) == yb)

    return params, store, loss_fn, acc_fn


def make_client_step(store: DeviceDataStore, loss_fn: Callable,
                     local_iters: int, batch_size: int, seed: int,
                     opt: Optimizer | None = None, lr: float = 0.01):
    """The live client's computation, jitted once: ``(global, k, seq) ->
    delta``.  Minibatches come from ``fold_in(fold_in(data_key, seq), k)``
    — the client's own stream, reproducible from ``(seed, k, seq)`` alone —
    and local SGD is the engine's own :func:`~repro.fl.engine.make_local_train`
    (a width-1 vmap lane of exactly what replay's phase B runs)."""
    from ..fl.engine import make_local_train

    data_key = data_stream_key(seed)
    vtrain = make_local_train(loss_fn, opt or sgd(lr))
    K = store.num_clients

    @jax.jit
    def step(g, k, seq):
        kc = jnp.clip(k, 0, K - 1)
        bidx = client_round_indices(data_key, seq, k, store.lengths[kc],
                                    local_iters, batch_size)
        xb, yb = store.x[kc][bidx], store.y[kc][bidx]
        g1 = jax.tree_util.tree_map(lambda p: p[None], g)
        trained = vtrain(g1, xb[None], yb[None])
        return jax.tree_util.tree_map(lambda a, b: (a - b)[0], trained, g1)

    return step


def run_loadgen(server, store: DeviceDataStore, loss_fn: Callable,
                lg: LoadGenConfig, opt: Optimizer | None = None) -> dict:
    """Drive a burst against a running server; returns the measured report.

    The server must have its batcher thread running (``start=True``) —
    tickets resolve asynchronously while workers keep submitting.
    """
    if server._batcher is None:
        raise ValueError("run_loadgen needs a running batcher "
                         "(AggregationServer(start=True))")
    cfg = server.cfg
    K = cfg.num_clients
    if store.num_clients != K:
        raise ValueError(f"store has {store.num_clients} clients, "
                         f"server expects {K}")
    step = make_client_step(store, loss_fn, cfg.local_iters, cfg.batch_size,
                            cfg.seed, opt=opt, lr=cfg.lr)
    rng0 = np.random.default_rng(lg.seed)
    if lg.rate_sigma > 0:
        weights = rng0.lognormal(0.0, lg.rate_sigma, K)
    else:
        weights = np.ones(K)
    weights = weights / weights.sum()

    lock = threading.Lock()
    seqs = np.zeros((K,), np.int64)
    tickets: list = []
    counts = {"admitted": 0, "skipped": 0, "busy": 0}
    rejects: dict[str, int] = {}
    deadline = time.perf_counter() + lg.timeout_s

    def worker(widx: int):
        rng = np.random.default_rng(lg.seed * 9973 + 7 * widx + 1)
        while True:
            with lock:
                if counts["admitted"] >= lg.uploads:
                    return
            if time.perf_counter() > deadline:
                return
            k = int(rng.choice(K, p=weights))
            if lg.pace_s > 0:
                time.sleep(float(rng.exponential(lg.pace_s)))
            if server.in_flight(k):      # advisory — saves the train compute
                with lock:
                    counts["busy"] += 1
                continue
            version, g = server.pull()
            if lg.respect_probs:
                if rng.random() >= float(server.transmit_probs()[k]):
                    with lock:
                        counts["skipped"] += 1
                    continue
            with lock:
                seq = int(seqs[k])
                seqs[k] += 1
            delta = jax.block_until_ready(step(g, k, seq))
            tk = server.submit(k, delta, version, seq=seq,
                               energy_j=server.upload_cost(k))
            with lock:
                if tk.admitted:
                    counts["admitted"] += 1
                    tickets.append(tk)
                else:
                    rejects[tk.reason] = rejects.get(tk.reason, 0) + 1

    tel = get_telemetry()
    t0 = time.perf_counter()
    with tel.span("serve.loadgen"):
        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(lg.workers)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=lg.timeout_s + 10.0)
        unresolved = 0
        for tk in tickets:
            if tk.wait(timeout=lg.ticket_wait_s) is None:
                unresolved += 1
    elapsed = time.perf_counter() - t0

    stats = server.stats()
    resolved = counts["admitted"] - unresolved
    report = {
        "clients": K,
        "uploads_admitted": counts["admitted"],
        "uploads_resolved": resolved,
        "uploads_unresolved": unresolved,
        "skipped_bernoulli": counts["skipped"],
        "skipped_busy": counts["busy"],
        "rejected": rejects,
        "elapsed_s": elapsed,
        "uploads_per_second": resolved / max(elapsed, 1e-9),
        "batches": stats.get("batches", 0),
        "admit_ms": stats.get("admit_ms", {}),
        "occupancy": stats.get("occupancy", {}),
        "distinct_clients": int(np.count_nonzero(seqs)),
    }
    emit_run_manifest(
        "serve_loadgen", lg,
        extra={"clients": K, "uploads_admitted": counts["admitted"],
               "uploads_per_second": report["uploads_per_second"],
               "batches": report["batches"]})
    return report
