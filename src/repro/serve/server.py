"""Async aggregation front door: the ingest layer.

The paper's clients "probabilistically transmit the local model to the
server at arbitrary times" — this module is that server.  Concurrent
client threads call :meth:`AggregationServer.submit` with
``(client_id, delta, local_version)`` at any moment; updates land in a
bounded pending set with **backpressure** (submissions beyond
``queue_capacity`` are rejected, never silently dropped) and **per-client
dedup** (one in-flight update per client — a client re-submitting before
its previous update aggregated is told to wait).  A background
:class:`~repro.serve.batcher.MicroBatcher` coalesces pending updates into
pow2-bucketed micro-batches and drives the same jitted
``subset_aggregate`` family as the scan engine.

The server also plays the paper's control plane: after every applied
micro-batch it re-solves the policy — by default the paper's (P1')
online solve (:func:`repro.core.selection.online_policy`) — against the
live ``(version, last_tx)`` ledger, and :meth:`transmit_probs` serves the
resulting per-client transmit probabilities ``p_{k,t}`` back to clients
(CSMAAFL contention or Hu–Chen–Larsson age-aware scheduling drop in as
alternative ``policy_fn``s, including ledger policies).

Every admitted micro-batch is appended to the
:class:`~repro.serve.replay.DecisionLog`; see :mod:`repro.serve.replay`
for the replay-parity contract.  Threading discipline: one condition
variable guards the pending set and ledgers; device work (the jitted
aggregation) runs outside the lock; a separate flush lock serializes
micro-batches so the version history is a total order.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig, rate_nats
from ..fl.faults import GuardConfig
from ..fl.state import AggregatorConfig
from ..obs.telemetry import emit_run_manifest, get_telemetry
from .batcher import MicroBatcher, build_apply_fn, pick_bucket
from .replay import BatchRecord, DecisionLog

_ADMISSION_KINDS = ("fifo", "age")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Front-door configuration (frozen ⇒ hashable, manifest-stampable).

    ``max_batch`` is the largest micro-batch (pow2 — it is the compiled
    bucket ceiling); ``min_bucket`` the smallest padded lane count (small
    flushes pad up to it so a handful of bucket shapes serve all traffic
    levels, exactly like ``participant_bucket`` in the sparse engine).
    ``flush_interval_s`` bounds admission latency: the batcher flushes
    early when the oldest pending update has waited that long.
    ``admission`` orders intake when pending > max_batch: ``"fifo"``
    (arrival order) or ``"age"`` (stalest local_version first — the
    Hu–Chen–Larsson priority at the admission boundary).
    ``local_iters``/``batch_size``/``lr``/``seed`` pin the client-side
    training contract recorded in the decision log.
    """

    num_clients: int
    queue_capacity: int = 256
    max_batch: int = 64
    min_bucket: int = 8
    flush_interval_s: float = 0.002
    admission: str = "fifo"
    local_iters: int = 1
    batch_size: int = 10
    lr: float = 0.01
    seed: int = 0
    guards: Optional[GuardConfig] = None
    aggregator: Optional[AggregatorConfig] = None
    # control plane: re-solve p_{k,t} in a background thread (the data
    # plane keeps aggregating against the previous solution — the paper's
    # (P1') online solve costs ~1s at K=10³, and stalling every micro-batch
    # on it collapses ingest throughput).  False = solve synchronously
    # inside flush (deterministic; what manual-flush tests want).
    policy_refresh_async: bool = True
    # floor between background re-solves: with a ~1s solve and ms-scale
    # micro-batches, solving after *every* batch just saturates the host —
    # the served p_{k,t} is allowed to lag the ledger by this much.
    policy_refresh_min_interval_s: float = 0.0

    def __post_init__(self):
        if self.num_clients < 1:
            raise ValueError("num_clients must be >= 1")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if self.max_batch < 1 or (self.max_batch & (self.max_batch - 1)):
            raise ValueError(f"max_batch must be a power of two, "
                             f"got {self.max_batch}")
        if not 1 <= self.min_bucket <= self.max_batch:
            raise ValueError("need 1 <= min_bucket <= max_batch")
        if self.admission not in _ADMISSION_KINDS:
            raise ValueError(f"unknown admission {self.admission!r} "
                             f"(expected one of {_ADMISSION_KINDS})")


class Ticket:
    """Submission receipt.  ``admitted`` is decided synchronously under the
    ingest lock; for admitted tickets :meth:`wait` blocks until the update
    aggregates and returns the first server version containing it."""

    __slots__ = ("client_id", "seq", "admitted", "reason", "arrival_s",
                 "_event", "_version")

    def __init__(self, client_id: int, seq: int, admitted: bool,
                 reason: str | None = None):
        self.client_id = client_id
        self.seq = seq
        self.admitted = admitted
        self.reason = reason
        self.arrival_s = time.perf_counter()
        self._event = threading.Event() if admitted else None
        self._version: int | None = None

    def done(self) -> bool:
        return bool(self._event and self._event.is_set())

    def wait(self, timeout: float | None = None) -> int | None:
        """Admitted version, or ``None`` on timeout / rejected ticket."""
        if self._event is None:
            return None
        if not self._event.wait(timeout):
            return None
        return self._version

    def _resolve(self, version: int) -> None:
        self._version = version
        self._event.set()


class _Pending(NamedTuple):
    ticket: Ticket
    delta: Any
    local_version: int
    energy_j: float


class _LedgerView(NamedTuple):
    """What ledger policies read (mirrors ``repro.fl.sparse._DecisionView``)."""

    round: jax.Array
    last_tx: jax.Array


class AggregationServer:
    """The micro-batching asynchronous FL aggregation server.

    ``params`` is the initial global model (any pytree).  ``policy_fn`` is
    an engine-native :data:`~repro.core.selection.PolicyFn` (state-free or
    ledger); ``gains`` feeds it per-refresh channel gains — an array
    ``[T_g, K]`` cycled by version, or a callable ``t -> [K]``.  ``cell``
    enables the eq.-5 upload-cost estimate served to clients.  With
    ``start=False`` no batcher thread runs — call :meth:`flush` manually
    (tests drive admission deterministically that way).
    """

    def __init__(self, params: Any, cfg: ServeConfig,
                 policy_fn: Callable | None = None, gains=None,
                 cell: CellConfig | None = None, start: bool = True):
        self.cfg = cfg
        self._tel = get_telemetry()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._flush_lock = threading.Lock()
        self._closed = False
        K = cfg.num_clients

        self._global = jax.tree_util.tree_map(jnp.asarray, params)
        self._version = 0
        self._last_tx = np.zeros((K,), np.int32)
        self._tx_count = np.zeros((K,), np.int64)
        self._energy = np.zeros((K,), np.float32)
        self._pending: dict[int, _Pending] = {}   # insertion-ordered
        self._seq_auto = np.zeros((K,), np.int64)

        self.log = DecisionLog(num_clients=K, seed=cfg.seed,
                               local_iters=cfg.local_iters,
                               batch_size=cfg.batch_size, lr=cfg.lr,
                               guards=cfg.guards, aggregator=cfg.aggregator)
        self._apply = build_apply_fn(cfg.guards, cfg.aggregator, K)

        self._policy_fn = policy_fn
        self._gains = gains
        self._cell = cell
        if policy_fn is not None:
            if gains is None:
                raise ValueError("a policy_fn needs `gains` (array [T, K] "
                                 "or callable t -> [K]) to evaluate p_{k,t}")
            self._policy_jit = jax.jit(
                lambda t, h, rnd, ltx: policy_fn(
                    t, h, _LedgerView(round=rnd, last_tx=ltx)))
        self._probs = np.ones((K,), np.float32)
        self._w = np.full((K,), 1.0 / K, np.float32)
        self._cost = np.zeros((K,), np.float32)
        self._refresh_policy()

        self._admit_latency_s: list[float] = []
        self._occupancy: list[tuple[int, int]] = []   # (n, bucket)

        self._policy_dirty = threading.Event()
        self._policy_stop = False
        self._policy_thread: threading.Thread | None = None
        if (policy_fn is not None and cfg.policy_refresh_async and start):
            self._policy_thread = threading.Thread(
                target=self._policy_loop, daemon=True,
                name="repro-serve-policy")
            self._policy_thread.start()

        emit_run_manifest("serve_session", cfg,
                          extra={"num_clients": K,
                                 "policy": getattr(policy_fn, "__name__",
                                                   str(policy_fn))})
        self._batcher: MicroBatcher | None = None
        if start:
            self._batcher = MicroBatcher(self)
            self._batcher.start()

    # -- client-facing API --------------------------------------------------

    def pull(self) -> tuple[int, Any]:
        """Current ``(version, global model)`` — what a client trains from."""
        with self._lock:
            return self._version, self._global

    def transmit_probs(self) -> np.ndarray:
        """The paper's ``p_{k,t}`` for the current version (copy)."""
        with self._lock:
            return self._probs.copy()

    def upload_cost(self, client_id: int) -> float:
        """Estimated eq.-5 upload energy (J) at the current allocation
        (0.0 when no ``cell`` was configured)."""
        with self._lock:
            return float(self._cost[client_id])

    def submit(self, client_id: int, delta: Any, local_version: int,
               seq: int | None = None, energy_j: float = 0.0) -> Ticket:
        """Offer one update.  Never blocks on device work; admission is
        decided immediately (backpressure/dedup/validation) and the
        decision returned on the :class:`Ticket`."""
        self._tel.inc("serve.submitted")
        with self._cv:
            k = int(client_id)
            in_range = 0 <= k < self.cfg.num_clients
            if seq is None:
                seq = int(self._seq_auto[k]) if in_range else -1
                if in_range:
                    self._seq_auto[k] += 1
            t = self._version
            if self._closed:
                reason = "closed"
            elif not in_range:
                reason = "bad_client"
            elif not 0 <= int(local_version) <= t:
                reason = "bad_version"
            elif k in self._pending:
                reason = "duplicate"
            elif len(self._pending) >= self.cfg.queue_capacity:
                reason = "backpressure"
            else:
                ticket = Ticket(k, int(seq), True)
                self._pending[k] = _Pending(ticket, delta,
                                            int(local_version),
                                            float(energy_j))
                self._tel.inc("serve.admitted")
                self._cv.notify_all()
                return ticket
            self._tel.inc(f"serve.rejected_{reason}")
            return Ticket(k, int(seq), False, reason=reason)

    # -- micro-batch plumbing (the batcher drives this) ---------------------

    def _take_locked(self) -> list[_Pending] | None:
        """Pop up to ``max_batch`` pending updates (caller holds the lock)."""
        if not self._pending:
            return None
        items = list(self._pending.values())
        if self.cfg.admission == "age":
            items.sort(key=lambda p: -(self._version - p.local_version))
        take = items[: self.cfg.max_batch]
        for p in take:
            del self._pending[p.ticket.client_id]
        return take

    def flush(self) -> int:
        """Apply one micro-batch (no-op on an empty queue).  Returns the
        number of updates aggregated.  Serialized: concurrent callers queue
        behind the flush lock, so versions advance one batch at a time."""
        with self._flush_lock:
            with self._cv:
                batch = self._take_locked()
                if batch is None:
                    return 0
                t = self._version
                g = self._global
            n = len(batch)
            bucket = pick_bucket(n, self.cfg.min_bucket, self.cfg.max_batch)
            ids = np.fromiter((p.ticket.client_id for p in batch), np.int64,
                              n)
            versions = np.fromiter((p.local_version for p in batch),
                                   np.int64, n)
            stale = t - versions
            probs = self._probs[ids]
            energy = np.fromiter((p.energy_j for p in batch), np.float32, n)
            deltas = [p.delta for p in batch]
            with self._tel.span("serve.flush"):
                g_new = self._apply(g, deltas, bucket,
                                    jnp.asarray(stale, jnp.int32),
                                    jnp.asarray(probs, jnp.float32))
                jax.block_until_ready(g_new)
            now = time.perf_counter()
            rec = BatchRecord(
                t=t, bucket=bucket, ids=tuple(int(i) for i in ids),
                versions=tuple(int(v) for v in versions),
                seqs=tuple(p.ticket.seq for p in batch),
                stale=tuple(int(s) for s in stale),
                probs=tuple(float(p) for p in probs),
                energy=tuple(float(e) for e in energy))
            with self._lock:
                self._global = g_new
                self._version = t + 1
                self._last_tx[ids] = t
                np.add.at(self._tx_count, ids, 1)
                np.add.at(self._energy, ids, energy)
                self.log.append(rec)
                self._occupancy.append((n, bucket))
                for p in batch:
                    self._admit_latency_s.append(now - p.ticket.arrival_s)
            if self._policy_thread is not None:
                self._policy_dirty.set()     # coalesced background re-solve
            else:
                self._refresh_policy()
            self._tel.inc("serve.batches")
            self._tel.inc("serve.uploads_aggregated", n)
            for p in batch:
                p.ticket._resolve(t + 1)
            return n

    def _policy_loop(self) -> None:
        """Background control plane: one re-solve per dirty signal, repeat
        flushes while a solve is in flight coalesce into a single refresh
        against the latest ledger, and at most one solve per
        ``policy_refresh_min_interval_s``."""
        interval = self.cfg.policy_refresh_min_interval_s
        last = -float("inf")
        while True:
            self._policy_dirty.wait()
            if self._policy_stop:
                return
            wait_s = interval - (time.perf_counter() - last)
            if wait_s > 0 and not self._policy_stop:
                time.sleep(wait_s)
            if self._policy_stop:
                return
            self._policy_dirty.clear()
            self._refresh_policy()
            last = time.perf_counter()

    def _refresh_policy(self) -> None:
        if self._policy_fn is None:
            return
        with self._lock:
            t = self._version
            ltx = jnp.asarray(self._last_tx)
        h_t = (self._gains(t) if callable(self._gains)
               else jnp.asarray(self._gains[t % len(self._gains)]))
        with self._tel.span("serve.policy_refresh"):
            p, w = self._policy_jit(jnp.int32(t), h_t, jnp.int32(t), ltx)
            p = np.asarray(jax.block_until_ready(p), np.float32)
            w = np.asarray(w, np.float32)
        if self._cell is not None:
            c = self._cell
            rate = np.asarray(rate_nats(jnp.asarray(w), h_t, c.tx_power_w,
                                        c.bandwidth_hz, c.noise_w_per_hz))
            cost = (c.tx_power_w * c.model_size_nats
                    / np.maximum(rate, 1e-30)).astype(np.float32)
        else:
            cost = self._cost
        with self._lock:
            self._probs, self._w, self._cost = p, w, cost

    # -- lifecycle / introspection ------------------------------------------

    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    def in_flight(self, client_id: int) -> bool:
        """Cheap pre-check: does this client already have a pending update?
        Advisory only (the authoritative dedup happens in :meth:`submit`) —
        it lets a load generator skip the local-train compute for a
        submission that would be rejected as a duplicate anyway."""
        with self._lock:
            return int(client_id) in self._pending

    def global_params(self) -> Any:
        with self._lock:
            return self._global

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def ledger_snapshot(self) -> dict:
        with self._lock:
            return {"version": self._version,
                    "last_tx": self._last_tx.copy(),
                    "tx_count": self._tx_count.copy(),
                    "energy": self._energy.copy()}

    def reset_stats(self) -> None:
        """Zero the latency/occupancy measurement windows (benchmarks call
        this after a warmup burst so compile time stays out of the steady-
        state numbers).  Ledgers and the decision log are untouched — the
        replay-parity contract always covers the whole session."""
        with self._lock:
            self._admit_latency_s.clear()
            self._occupancy.clear()

    def stats(self) -> dict:
        """Latency / occupancy summary for the session so far."""
        with self._lock:
            lat = np.asarray(self._admit_latency_s, np.float64)
            occ = list(self._occupancy)
        out = {"batches": len(occ),
               "uploads": int(sum(n for n, _ in occ))}
        if len(lat):
            out["admit_ms"] = {
                "p50": float(np.percentile(lat, 50) * 1e3),
                "p95": float(np.percentile(lat, 95) * 1e3),
                "p99": float(np.percentile(lat, 99) * 1e3),
                "max": float(lat.max() * 1e3)}
        if occ:
            fills = [n / b for n, b in occ]
            out["occupancy"] = {"mean": float(np.mean(fills)),
                                "min": float(np.min(fills)),
                                "mean_batch": float(np.mean(
                                    [n for n, _ in occ]))}
        return out

    def close(self, drain: bool = True) -> None:
        """Stop admitting, stop the batcher, optionally flush the queue dry
        (every admitted ticket resolves — the no-drop invariant)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._batcher is not None:
            self._batcher.stop()
            self._batcher = None
        if self._policy_thread is not None:
            self._policy_stop = True
            self._policy_dirty.set()
            self._policy_thread.join(timeout=30)
            self._policy_thread = None
        if drain:
            while self.flush():
                pass

    def __enter__(self) -> "AggregationServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
