"""Async aggregation front door (docs/serving.md).

A long-running micro-batching FL server next to the dense/legacy/sparse
simulation paths: concurrent clients submit ``(client_id, delta,
local_version)`` at arbitrary times; a background batcher coalesces them
into pow2 buckets and drives the scan engine's own jitted
participant-subset aggregation; every admitted micro-batch lands in a
decision log that replays bit-for-bit through
:func:`repro.fl.sparse.build_sparse_train_program`.

* :mod:`repro.serve.server` — ingest: bounded queue, backpressure,
  per-client dedup, the ``p_{k,t}`` policy refresh.
* :mod:`repro.serve.batcher` — pow2 micro-batching + the jitted apply.
* :mod:`repro.serve.replay` — decision log + offline replay parity.
* :mod:`repro.serve.loadgen` — emulated client population + measurements.
"""
from .batcher import MicroBatcher, build_apply_fn, pick_bucket
from .loadgen import LoadGenConfig, make_client_step, run_loadgen, toy_world
from .replay import (BatchRecord, DecisionLog, ReplayResult,
                     gather_logged_rounds, replay_ledgers, replay_session,
                     verify_replay)
from .server import AggregationServer, ServeConfig, Ticket

__all__ = [
    "AggregationServer", "ServeConfig", "Ticket", "MicroBatcher",
    "build_apply_fn", "pick_bucket", "BatchRecord", "DecisionLog",
    "ReplayResult", "gather_logged_rounds", "replay_ledgers",
    "replay_session", "verify_replay", "LoadGenConfig", "make_client_step",
    "run_loadgen", "toy_world",
]
