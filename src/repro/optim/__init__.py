"""Minimal optimizer library (optax-style pure functions, no dependency).

The paper trains with plain SGD (lr 0.01); momentum and Adam are provided for
the beyond-paper experiments and the mega-arch trainer.
"""
from .optim import Optimizer, adam, momentum, sgd

__all__ = ["Optimizer", "sgd", "momentum", "adam"]
