"""Pure-function optimizers over pytrees."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        upd = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return upd, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params):
        state = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        upd = jax.tree_util.tree_map(lambda m: -lr * m, state)
        return upd, state

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        return (zeros, jax.tree_util.tree_map(jnp.zeros_like, params),
                jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        m, v, t = state
        t = t + 1
        m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
        v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m_, v_: -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), m, v)
        return upd, (m, v, t)

    return Optimizer(init, update)


def apply_updates(params, upd):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, upd)
