"""Observability: in-scan metrics taps, host telemetry, benchmark reporter.

Three layers (docs/observability.md):

* :mod:`repro.obs.taps` — device-side metrics: a :class:`MetricsSpec` of
  pure jittable reducers accumulated into fixed-shape buffers threaded
  through the scan carry of every execution path.  Disabled (the default)
  the engine programs are byte-for-byte unchanged.
* :mod:`repro.obs.telemetry` — host-side: timing spans, compile-cache
  counters, device-memory snapshots, a structured JSONL run manifest
  (opt-in via ``REPRO_OBS_DIR``), and a ``jax.profiler`` capture hook
  (opt-in via ``REPRO_PROFILE_DIR``).
* :mod:`repro.obs.report` — the benchmark ledger reporter: renders run
  summaries and diffs two BENCH_*.json files with tolerance thresholds
  (the CI perf-regression gate).
"""
from .taps import (MetricsSpec, MetricsState, init_metrics, merge_metrics,
                   metrics_active, metrics_round_update, metrics_summary,
                   update_ledger_taps, update_train_taps)
from .telemetry import (config_fingerprint, configure, emit_run_manifest,
                        env_fingerprint, get_telemetry, maybe_profile,
                        run_manifest, timed_compile, validate_manifest)

__all__ = [
    "MetricsSpec", "MetricsState", "init_metrics", "merge_metrics",
    "metrics_active", "metrics_round_update", "metrics_summary",
    "update_ledger_taps", "update_train_taps",
    "config_fingerprint", "configure", "emit_run_manifest",
    "env_fingerprint", "get_telemetry", "maybe_profile", "run_manifest",
    "timed_compile", "validate_manifest",
]
