"""Host-side telemetry: spans, counters, memory snapshots, run manifests.

Everything here is host Python around the device programs — it never
changes a traced program.  The in-memory session record is always on (it is
just dict updates); *writing* anything to disk is opt-in:

* ``REPRO_OBS_DIR`` (or :func:`configure`) — run manifests append to
  ``<dir>/runs.jsonl`` as one JSON object per line (schema:
  :data:`MANIFEST_SCHEMA`, checked by :func:`validate_manifest` and the CI
  obs-smoke job);
* ``REPRO_PROFILE_DIR`` (or :func:`configure`) — :func:`maybe_profile`
  wraps a block in ``jax.profiler.trace`` emitting a TensorBoard trace.

Spans aggregate per name (count / total / max seconds) so a million runner
calls cost a bounded dict, not an unbounded event log.  The sparse train
compile cache (:mod:`repro.fl.sparse`) bumps the
``sparse.train_cache_{hit,miss}`` counters here.
"""
from __future__ import annotations

import contextlib
import functools
import hashlib
import json
import os
import subprocess
import time
from typing import Any

__all__ = ["Telemetry", "get_telemetry", "configure", "env_fingerprint",
           "config_fingerprint", "run_manifest", "emit_run_manifest",
           "validate_manifest", "maybe_profile", "timed_compile",
           "MANIFEST_SCHEMA", "MANIFEST_SCHEMA_VERSION"]

MANIFEST_SCHEMA_VERSION = 1

#: required manifest keys -> type (the JSONL validation contract; ``extra``
#: is free-form).  ``fingerprint`` is the environment block from
#: :func:`env_fingerprint`; ``config_sha`` hashes the SimConfig repr.
MANIFEST_SCHEMA = {
    "schema_version": int,
    "kind": str,
    "written_unix": float,
    "config_sha": str,
    "fingerprint": dict,
    "extra": dict,
}

_FINGERPRINT_KEYS = ("git_sha", "jax", "jaxlib", "backend", "device_count",
                     "cpu_count", "platform", "python")

#: cap on the in-memory manifest record (append-only; old entries rotate).
_MAX_MANIFESTS = 256


class Telemetry:
    """Process-wide aggregation sink: counters, named spans, manifests."""

    def __init__(self):
        self.reset()

    def reset(self) -> None:
        self.counters: dict = {}
        self.spans: dict = {}          # name -> [count, total_s, max_s]
        self.manifests: list = []

    def inc(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    @contextlib.contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            c = self.spans.setdefault(name, [0, 0.0, 0.0])
            c[0] += 1
            c[1] += dt
            c[2] = max(c[2], dt)

    def span_stats(self, name: str) -> dict | None:
        c = self.spans.get(name)
        if c is None:
            return None
        return {"count": c[0], "total_s": c[1], "max_s": c[2],
                "mean_s": c[1] / max(c[0], 1)}

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "spans": {k: self.span_stats(k) for k in self.spans}}

    def memory_snapshot(self) -> list:
        """Per-device memory stats where the backend exposes them (TPU/GPU;
        CPU backends typically return an empty stats dict)."""
        import jax

        out = []
        for d in jax.devices():
            try:
                stats = d.memory_stats() or {}
            except Exception:
                stats = {}
            out.append({"device": str(d),
                        "bytes_in_use": stats.get("bytes_in_use"),
                        "peak_bytes_in_use": stats.get("peak_bytes_in_use")})
        return out


_TELEMETRY = Telemetry()
_OBS_DIR: str | None = None
_PROFILE_DIR: str | None = None


def get_telemetry() -> Telemetry:
    return _TELEMETRY


def configure(obs_dir: str | None = None,
              profile_dir: str | None = None) -> None:
    """Programmatic opt-in (overrides the environment variables)."""
    global _OBS_DIR, _PROFILE_DIR
    if obs_dir is not None:
        _OBS_DIR = obs_dir
    if profile_dir is not None:
        _PROFILE_DIR = profile_dir


def _obs_dir() -> str | None:
    return _OBS_DIR or os.environ.get("REPRO_OBS_DIR") or None


def _profile_dir() -> str | None:
    return _PROFILE_DIR or os.environ.get("REPRO_PROFILE_DIR") or None


@functools.lru_cache(maxsize=1)
def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def env_fingerprint() -> dict:
    """Where/what produced an artifact: git sha, jax/jaxlib versions,
    backend, device/CPU counts.  Stamped into every BENCH_*.json
    (``benchmarks/common.py``) and every run manifest — without it the
    ledger's numbers are uncomparable across machines."""
    import platform

    import jax

    try:
        import jaxlib
        jaxlib_v = getattr(jaxlib, "__version__", "unknown")
    except Exception:
        jaxlib_v = "unknown"
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib_v,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }


def config_fingerprint(cfg: Any) -> str:
    """Short stable hash of a config's repr (SimConfig is a frozen
    dataclass — its repr is its full field map)."""
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def run_manifest(kind: str, cfg: Any = None, extra: dict | None = None) -> dict:
    return {
        "schema_version": MANIFEST_SCHEMA_VERSION,
        "kind": kind,
        "written_unix": time.time(),
        "config_sha": config_fingerprint(cfg) if cfg is not None else "",
        "fingerprint": env_fingerprint(),
        "extra": dict(extra or {}),
    }


def emit_run_manifest(kind: str, cfg: Any = None,
                      extra: dict | None = None) -> dict:
    """Record a manifest in the session telemetry and — when an obs dir is
    configured — append it to ``<dir>/runs.jsonl``.  Called by
    ``make_runner``, the ``run_*_matrix`` fan-outs, and ``run_resumable``;
    with no dir configured this is a dict append, nothing touches disk."""
    m = run_manifest(kind, cfg, extra)
    tel = get_telemetry()
    tel.manifests.append(m)
    del tel.manifests[:-_MAX_MANIFESTS]
    d = _obs_dir()
    if d:
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "runs.jsonl"), "a") as f:
            f.write(json.dumps(m, default=float) + "\n")
    return m


def validate_manifest(m: dict) -> list:
    """Schema check: returns a list of problems (empty = valid)."""
    problems = []
    if not isinstance(m, dict):
        return [f"manifest is {type(m).__name__}, expected dict"]
    for key, typ in MANIFEST_SCHEMA.items():
        if key not in m:
            problems.append(f"missing key {key!r}")
        elif typ is float and isinstance(m[key], (int, float)):
            pass
        elif not isinstance(m[key], typ):
            problems.append(f"key {key!r}: {type(m[key]).__name__}, "
                            f"expected {typ.__name__}")
    fp = m.get("fingerprint")
    if isinstance(fp, dict):
        for k in _FINGERPRINT_KEYS:
            if k not in fp:
                problems.append(f"fingerprint missing {k!r}")
    return problems


@contextlib.contextmanager
def maybe_profile(out_dir: str | None = None):
    """Opt-in ``jax.profiler`` capture: a no-op unless ``out_dir`` is given
    or ``REPRO_PROFILE_DIR``/:func:`configure` set one."""
    d = out_dir or _profile_dir()
    if not d:
        yield None
        return
    import jax

    os.makedirs(d, exist_ok=True)
    with jax.profiler.trace(d):
        yield d


def timed_compile(fn, *args, label: str = "jit"):
    """AOT-compile ``fn(*args)`` with spans around each stage —
    ``<label>.trace`` / ``<label>.lower`` / ``<label>.compile`` (older jax
    folds trace into lower) — and return the compiled executable.  Wrap its
    calls in ``span(f"{label}.execute")`` to complete the pipeline timing."""
    import jax

    tel = get_telemetry()
    jf = fn if hasattr(fn, "lower") else jax.jit(fn)
    if hasattr(jf, "trace"):
        with tel.span(f"{label}.trace"):
            traced = jf.trace(*args)
        with tel.span(f"{label}.lower"):
            lowered = traced.lower()
    else:
        with tel.span(f"{label}.lower"):
            lowered = jf.lower(*args)
    with tel.span(f"{label}.compile"):
        return lowered.compile()
