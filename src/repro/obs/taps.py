"""Device-side metrics taps: pure jittable reducers in the scan carry.

The paper's argument is about *dynamics* — staleness Δ_k, per-client energy
(eq. 5), the selection-probability trade-off — but a scan that only reads
back end-of-run curves cannot show them.  A :class:`MetricsSpec` turns on a
set of per-round reducers whose accumulators are fixed-shape device buffers
carried through the scan:

* **participation counts** — ``tx_count [K] i32``: how often each client's
  Bernoulli/Δ_k decision fired (the realized selection distribution);
* **staleness histogram** — ``stale_hist [bins] i32``: Δτ at transmission
  time over *delivered* uploads (last bin is open-ended);
* **energy by cause** — ``energy_cause [3] f32``: eq.-5 Joules split into
  voluntary uploads, Δ_k-forced uploads, and retry overhead paid to the
  lossy-uplink fault process;
* **guard interventions** — ``guard_events [3] i32``: per-round counts of
  quarantined (non-finite), norm-clipped, and staleness-capped updates
  (only materialized when ``cfg.guards`` is active);
* **aggregation-weight stats** — ``weight_entropy``/``weight_max``: entropy
  of the normalized per-round aggregation weights (summed over rounds) and
  the running max weight — how concentrated the global update is.

Design rules the engines rely on:

* **bit-parity when disabled** — ``SimConfig.metrics=None`` (the default)
  adds nothing to any carry or program; the golden traces and every parity
  test run unchanged.  Taps are read-only: enabling them never perturbs the
  simulated trajectory either.
* **fixed shapes, None leaves** — disabled individual taps are ``None``
  fields of the :class:`MetricsState` NamedTuple.  ``None`` is pytree
  *structure*, not a leaf, so any tap subset is jit/vmap-safe (the matrix
  runners fan MetricsState out over their lane axes like any other carry).
* **split accumulation** — the sparse two-phase path computes the ledger
  taps (participation/staleness/energy) in one batched post-scan reduction
  over phase A's ``[T, P]`` participation-trace lanes (its sequential scan
  carries no tap state) and the train taps (guards/weights) in phase B's
  bucket program; :func:`merge_metrics` joins the halves.  Integer taps
  agree exactly with the dense engine; float reductions agree to
  float-associativity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["MetricsSpec", "MetricsState", "init_metrics", "metrics_active",
           "update_ledger_taps", "update_train_taps", "metrics_round_update",
           "merge_metrics", "metrics_summary"]


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """Which in-scan reducers to run (frozen ⇒ usable in jitted closures).

    The default constructor is the *default tap set* — everything on — whose
    per-round overhead is bounded by ``benchmarks/bench_obs.py`` (a few
    [K]-vector ops against the K·L·B local-training cost).
    ``MetricsSpec.none()`` is all-off, which must trace to the identical
    program as ``metrics=None`` (tests pin the jaxpr).
    """

    participation: bool = True     # tx_count [K]
    staleness_hist: bool = True    # stale_hist [staleness_bins]
    staleness_bins: int = 8        # linear bins 0..bins-2, last bin open
    energy_by_cause: bool = True   # energy_cause [3]
    guard_events: bool = True      # guard_events [3] (needs active guards)
    weight_stats: bool = True      # weight_entropy / weight_max scalars

    def __post_init__(self):
        if self.staleness_bins < 2:
            raise ValueError("staleness_bins must be >= 2 "
                             f"(got {self.staleness_bins})")

    @classmethod
    def none(cls) -> "MetricsSpec":
        return cls(participation=False, staleness_hist=False,
                   energy_by_cause=False, guard_events=False,
                   weight_stats=False)

    @property
    def ledger_active(self) -> bool:
        """Taps computable from the [K] decision/ledger vectors alone."""
        return (self.participation or self.staleness_hist
                or self.energy_by_cause)

    def train_active(self, guards=None) -> bool:
        """Taps that need the deltas / aggregation weights."""
        return self.weight_stats or (
            self.guard_events and guards is not None
            and getattr(guards, "active", False))


class MetricsState(NamedTuple):
    """Fixed-shape accumulators; a disabled tap's field is ``None`` (pytree
    structure, not a leaf — vmap/jit treat any subset uniformly)."""

    tx_count: Any = None        # [K] i32 — decision-mask fires per client
    stale_hist: Any = None      # [bins] i32 — Δτ of delivered uploads
    energy_cause: Any = None    # [3] f32 — (voluntary, forced, retry)
    guard_events: Any = None    # [3] i32 — (quarantined, clipped, capped)
    weight_entropy: Any = None  # scalar f32 — Σ_rounds H(normalized weights)
    weight_max: Any = None      # scalar f32 — running max weight
    rounds: Any = None          # scalar i32 — ledger rounds accumulated
    agg_rounds: Any = None      # scalar i32 — train rounds accumulated


def metrics_active(spec: MetricsSpec | None, guards=None,
                   parts: str = "all") -> bool:
    """Would :func:`init_metrics` materialize any buffer?  Pure predicate —
    the engines use it to decide the carry structure, so it must agree with
    :func:`init_metrics` exactly."""
    if spec is None:
        return False
    ledger = parts in ("all", "ledger") and spec.ledger_active
    train = parts in ("all", "train") and spec.train_active(guards)
    return ledger or train


def init_metrics(spec: MetricsSpec | None, num_clients: int, guards=None,
                 parts: str = "all") -> MetricsState | None:
    """Zeroed accumulators for the enabled taps, or ``None`` when nothing is
    enabled (the carry then stays byte-identical to the untapped program).

    ``parts`` selects the accumulator subset for the sparse path's split
    accumulation: ``"ledger"`` (phase A), ``"train"`` (phase B), or
    ``"all"`` (dense scan / legacy loop).
    """
    if not metrics_active(spec, guards, parts):
        return None
    ledger = parts in ("all", "ledger") and spec.ledger_active
    train = parts in ("all", "train") and spec.train_active(guards)
    ge = (train and spec.guard_events and guards is not None
          and getattr(guards, "active", False))
    ws = train and spec.weight_stats
    return MetricsState(
        tx_count=(jnp.zeros((num_clients,), jnp.int32)
                  if ledger and spec.participation else None),
        stale_hist=(jnp.zeros((spec.staleness_bins,), jnp.int32)
                    if ledger and spec.staleness_hist else None),
        energy_cause=(jnp.zeros((3,), jnp.float32)
                      if ledger and spec.energy_by_cause else None),
        guard_events=jnp.zeros((3,), jnp.int32) if ge else None,
        weight_entropy=jnp.zeros((), jnp.float32) if ws else None,
        weight_max=jnp.zeros((), jnp.float32) if ws else None,
        rounds=jnp.zeros((), jnp.int32) if ledger else None,
        agg_rounds=jnp.zeros((), jnp.int32) if train else None,
    )


def update_ledger_taps(ms: MetricsState, spec: MetricsSpec, *,
                       mask: jax.Array, forced: jax.Array,
                       e_base: jax.Array, e_round: jax.Array,
                       staleness: jax.Array,
                       delivered: jax.Array) -> MetricsState:
    """One round of the [K]-vector taps (dense round step and legacy loop;
    sparse phase A reduces the same quantities post-scan from participant
    trace lanes, bit-exact for the integer accumulators because the lanes
    are exactly the mask fires).

    ``e_base`` is the eq.-5 decision energy *before* the fault pipeline,
    ``e_round`` what was actually paid (retry multipliers, dropped uploads);
    the retry-overhead lane is ``Σ relu(e_round − e_base)``.
    """
    upd = {}
    if ms.tx_count is not None:
        upd["tx_count"] = ms.tx_count + (mask > 0).astype(jnp.int32)
    if ms.stale_hist is not None:
        bins = ms.stale_hist.shape[0]
        b = jnp.clip(staleness.astype(jnp.int32), 0, bins - 1)
        upd["stale_hist"] = ms.stale_hist.at[b].add(
            (delivered > 0).astype(jnp.int32))
    if ms.energy_cause is not None:
        f = forced.astype(jnp.float32)
        e = e_round.astype(jnp.float32)
        retry = jnp.maximum(e - e_base.astype(jnp.float32), 0.0)
        upd["energy_cause"] = ms.energy_cause + jnp.stack(
            [jnp.sum(e * (1.0 - f)), jnp.sum(e * f), jnp.sum(retry)])
    if ms.rounds is not None:
        upd["rounds"] = ms.rounds + 1
    return ms._replace(**upd)


def _effective_weights(deltas, delivered, staleness, probs, num_clients,
                       guards, agg_params):
    """Mirror of the engines' aggregation-weight choice (state.py): guard
    weights fold into the delivery mask, then either the pluggable scheme
    weights or the paper's m/K.  Recomputed here (a few row-vector ops) so
    the aggregation functions keep their signatures and the untapped
    program stays untouched."""
    from ..fl.state import guard_weights, scheme_weights

    m = delivered.astype(jnp.float32)
    if guards is not None and getattr(guards, "active", False):
        gw, _ = guard_weights(deltas, staleness, guards)
        m = m * gw
    if agg_params is not None:
        return scheme_weights(m, staleness, probs, agg_params, num_clients)
    return m / jnp.asarray(num_clients, jnp.float32)


def update_train_taps(ms: MetricsState, spec: MetricsSpec, *,
                      deltas: Any, delivered: jax.Array,
                      staleness: jax.Array, probs: jax.Array,
                      num_clients, guards=None,
                      agg_params=None) -> MetricsState:
    """One round of the delta/weight taps.  The row axis may be the
    population (dense/legacy) or the participant bucket (sparse phase B) —
    counts agree exactly, float reductions to associativity."""
    from ..fl.state import finite_rows, update_norms

    upd = {}
    dlv = (delivered > 0) if delivered.dtype != jnp.bool_ else delivered
    if ms.guard_events is not None:
        q = dlv & ~finite_rows(deltas)
        if guards.clip_norm is not None:
            c = dlv & (update_norms(deltas) > guards.clip_norm)
        else:
            c = jnp.zeros(dlv.shape, bool)
        if guards.staleness_cap is not None:
            s = dlv & (staleness > guards.staleness_cap)
        else:
            s = jnp.zeros(dlv.shape, bool)
        upd["guard_events"] = ms.guard_events + jnp.stack(
            [jnp.sum(q.astype(jnp.int32)), jnp.sum(c.astype(jnp.int32)),
             jnp.sum(s.astype(jnp.int32))])
    if ms.weight_entropy is not None:
        a = _effective_weights(deltas, dlv, staleness, probs, num_clients,
                               guards, agg_params)
        tot = jnp.maximum(jnp.sum(a), 1e-30)
        p = a / tot
        ent = -jnp.sum(jnp.where(a > 0, p * jnp.log(jnp.maximum(p, 1e-30)),
                                 0.0))
        upd["weight_entropy"] = ms.weight_entropy + ent
        upd["weight_max"] = jnp.maximum(ms.weight_max, jnp.max(a))
    if ms.agg_rounds is not None:
        upd["agg_rounds"] = ms.agg_rounds + 1
    return ms._replace(**upd)


def metrics_round_update(ms: MetricsState, spec: MetricsSpec, *,
                         mask, forced, e_base, e_round, staleness,
                         delivered, deltas, probs, num_clients,
                         guards=None, agg_params=None) -> MetricsState:
    """The dense round step's one-call update: ledger taps + train taps."""
    ms = update_ledger_taps(ms, spec, mask=mask, forced=forced,
                            e_base=e_base, e_round=e_round,
                            staleness=staleness, delivered=delivered)
    if ms.agg_rounds is not None:
        ms = update_train_taps(ms, spec, deltas=deltas, delivered=delivered,
                               staleness=staleness, probs=probs,
                               num_clients=num_clients, guards=guards,
                               agg_params=agg_params)
    return ms


def merge_metrics(a: MetricsState | None,
                  b: MetricsState | None) -> MetricsState | None:
    """Join split accumulations (sparse phase A ledger + phase B train):
    fieldwise, taking whichever half materialized the buffer."""
    if a is None:
        return b
    if b is None:
        return a
    return MetricsState(*[(x if x is not None else y)
                          for x, y in zip(a, b)])


def metrics_summary(ms: MetricsState | None) -> dict:
    """Host-side readback: one dict of plain numbers/lists per enabled tap
    (manifest- and JSON-friendly)."""
    import numpy as np

    if ms is None:
        return {}
    out = {}
    if ms.tx_count is not None:
        tx = np.asarray(ms.tx_count)
        out["tx_count"] = tx.tolist()
        out["tx_total"] = int(tx.sum())
    if ms.stale_hist is not None:
        out["stale_hist"] = np.asarray(ms.stale_hist).tolist()
    if ms.energy_cause is not None:
        e = np.asarray(ms.energy_cause)
        out["energy_voluntary"] = float(e[0])
        out["energy_forced"] = float(e[1])
        out["energy_retry_overhead"] = float(e[2])
    if ms.guard_events is not None:
        g = np.asarray(ms.guard_events)
        out["guard_quarantined"] = int(g[0])
        out["guard_clipped"] = int(g[1])
        out["guard_stale_capped"] = int(g[2])
    if ms.weight_entropy is not None:
        n = max(int(np.asarray(ms.agg_rounds)), 1)
        out["weight_entropy_mean"] = float(np.asarray(ms.weight_entropy)) / n
        out["weight_max"] = float(np.asarray(ms.weight_max))
    if ms.rounds is not None:
        out["rounds"] = int(np.asarray(ms.rounds))
    return out
