"""Benchmark-ledger reporter: summarize runs, diff two BENCH_*.json files.

Three subcommand-style modes (one per CI need)::

    python -m repro.obs.report --summary obs_out/runs.jsonl
    python -m repro.obs.report --validate obs_out/runs.jsonl
    python -m repro.obs.report --diff BENCH_old.json BENCH_new.json \
        --threshold 1.25 [--keys engine]

``--validate`` checks every JSONL line against :data:`MANIFEST_SCHEMA` and
exits 1 on the first malformed manifest.  ``--diff`` flattens the numeric
scalar leaves shared by both files and compares them: keys whose leaf name
ends in a time suffix (``_s``/``_ms``/``_us``/``_sec``/``_seconds``) are
*lower-is-better* and **gate** — a new/old ratio above the threshold is a
regression and the process exits 1 (the CI perf gate); every other shared
numeric key is reported informationally.  Environment-stamp keys
(``fingerprint``, ``written_unix``, ``schema`` …) are skipped, since they
legitimately differ between runs.
"""
from __future__ import annotations

import argparse
import json
import sys

from .telemetry import validate_manifest

#: leaf-name suffixes treated as timings (lower is better, gated on diff).
TIME_SUFFIXES = ("_s", "_ms", "_us", "_sec", "_seconds")

#: top-level / leaf keys that are stamps, not measurements.
SKIP_KEYS = {"fingerprint", "written_unix", "schema", "schema_version",
             "config_sha", "git_sha"}

#: bases smaller than this are noise — ratios against them are meaningless.
MIN_BASE = 1e-9


def flatten_numeric(obj, prefix: str = "", out: dict | None = None) -> dict:
    """``{"a": {"b": [1.5, 2]}} -> {"a.b[0]": 1.5, "a.b[1]": 2.0}`` keeping
    only int/float scalar leaves (bools excluded) and skipping stamp keys."""
    if out is None:
        out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k in SKIP_KEYS:
                continue
            flatten_numeric(v, f"{prefix}.{k}" if prefix else str(k), out)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            flatten_numeric(v, f"{prefix}[{i}]", out)
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def is_time_key(key: str) -> bool:
    leaf = key.rsplit(".", 1)[-1]
    leaf = leaf.split("[", 1)[0]
    return leaf.endswith(TIME_SUFFIXES)


def diff_benches(old: dict, new: dict, threshold: float,
                 key_filter: str | None = None) -> dict:
    """Compare shared numeric leaves.  Returns ``{"rows": [...],
    "regressions": [...], "missing": [...], "added": [...]}`` where each row
    is ``(key, old, new, ratio, gated)``."""
    fo, fn = flatten_numeric(old), flatten_numeric(new)
    if key_filter:
        fo = {k: v for k, v in fo.items() if key_filter in k}
        fn = {k: v for k, v in fn.items() if key_filter in k}
    rows, regressions = [], []
    for k in sorted(set(fo) & set(fn)):
        o, n = fo[k], fn[k]
        gated = is_time_key(k)
        if abs(o) < MIN_BASE:
            ratio = None          # near-zero base: report, never gate
        else:
            ratio = n / o
        rows.append({"key": k, "old": o, "new": n, "ratio": ratio,
                     "gated": gated})
        if gated and ratio is not None and ratio > threshold:
            regressions.append(rows[-1])
    return {"rows": rows, "regressions": regressions,
            "missing": sorted(set(fo) - set(fn)),
            "added": sorted(set(fn) - set(fo))}


def render_diff(d: dict, threshold: float) -> str:
    lines = [f"{'key':<56} {'old':>12} {'new':>12} {'ratio':>8}  gate"]
    for r in d["rows"]:
        ratio = "n/a" if r["ratio"] is None else f"{r['ratio']:.3f}"
        flag = ""
        if r["gated"]:
            flag = "REGRESSED" if r in d["regressions"] else "ok"
        lines.append(f"{r['key']:<56} {r['old']:>12.6g} {r['new']:>12.6g} "
                     f"{ratio:>8}  {flag}")
    for k in d["missing"]:
        lines.append(f"{k:<56} (removed in new)")
    for k in d["added"]:
        lines.append(f"{k:<56} (new key)")
    n_gated = sum(1 for r in d["rows"] if r["gated"])
    lines.append(f"-- {len(d['rows'])} shared keys, {n_gated} gated at "
                 f"{threshold:.2f}x, {len(d['regressions'])} regression(s)")
    return "\n".join(lines)


def load_jsonl(path: str) -> list:
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i + 1}: bad JSON line: {e}")
    return out


def summarize_runs(manifests: list) -> str:
    lines = [f"{len(manifests)} run manifest(s)"]
    by_kind: dict = {}
    for m in manifests:
        by_kind.setdefault(m.get("kind", "?"), []).append(m)
    for kind, ms in sorted(by_kind.items()):
        fp = ms[-1].get("fingerprint", {}) or {}
        lines.append(f"  {kind:<24} x{len(ms):<4} backend={fp.get('backend')}"
                     f" devices={fp.get('device_count')}"
                     f" jax={fp.get('jax')} git={str(fp.get('git_sha'))[:9]}")
        extra = ms[-1].get("extra", {}) or {}
        for k in sorted(extra)[:8]:
            v = extra[k]
            if isinstance(v, float):
                v = f"{v:.6g}"
            lines.append(f"      {k} = {v}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="Benchmark-ledger reporter / perf-regression gate.")
    ap.add_argument("--summary", metavar="RUNS_JSONL",
                    help="render a summary of a runs.jsonl manifest log")
    ap.add_argument("--validate", metavar="RUNS_JSONL",
                    help="schema-check every manifest line; exit 1 if any fail")
    ap.add_argument("--diff", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two BENCH_*.json files; exit 1 on regression")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="gated-ratio bound for --diff (default 1.25)")
    ap.add_argument("--keys", default=None,
                    help="only diff keys containing this substring")
    args = ap.parse_args(argv)

    if not (args.summary or args.validate or args.diff):
        ap.error("one of --summary / --validate / --diff is required")

    rc = 0
    if args.validate:
        manifests = load_jsonl(args.validate)
        bad = 0
        for i, m in enumerate(manifests):
            problems = validate_manifest(m)
            for p in problems:
                print(f"{args.validate}:{i + 1}: {p}")
            bad += bool(problems)
        print(f"{len(manifests) - bad}/{len(manifests)} manifests valid")
        if bad or not manifests:
            rc = 1
    if args.summary:
        print(summarize_runs(load_jsonl(args.summary)))
    if args.diff:
        with open(args.diff[0]) as f:
            old = json.load(f)
        with open(args.diff[1]) as f:
            new = json.load(f)
        d = diff_benches(old, new, args.threshold, args.keys)
        print(render_diff(d, args.threshold))
        if d["regressions"]:
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
