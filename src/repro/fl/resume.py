"""Resumable scan execution: strided param checkpoints + kill-and-resume.

A long-horizon sweep that dies at round 1900 of 2000 used to restart from
round 0 — nothing inside one monolithic ``lax.scan`` survives the process.
This driver splits the horizon into ``cfg.checkpoint_every``-round segments
and runs the *identical* round transition (:func:`repro.fl.engine.
build_chunk_sim` — same ``fold_in`` PRNG/data streams, absolute round ids)
segment by segment, persisting the scan carry after each one:

* **checkpoint stride** — after segment ``i`` the full carry
  (``FLState``, energy ledger, fault state when injection is on) is written
  to ``<ckpt_dir>/seg_i`` via :mod:`repro.checkpoint`, the segment's round
  trace to ``seg_i_trace.npz``, and a ``seg_i.done`` marker commits the
  pair (a crash mid-write leaves no marker — the segment simply reruns).
* **resume** — the next :func:`run_resumable` call on the same directory
  verifies the run fingerprint (horizon, seed, K, fault/guard configs),
  restores the last committed carry, and continues from the first
  incomplete segment.  Because segment boundaries change neither the PRNG
  streams nor the op order, a killed-and-resumed run reproduces the
  uninterrupted run's final params **bit-exactly** (``tests/test_resume.py``
  pins this, faults included).
* **post-hoc replay evals** — with ``cfg.eval_mode="replay"`` the scan body
  contains no ``lax.cond`` eval at all (under ``vmap`` both branches of the
  old in-scan pattern executed every round); the driver instead evaluates
  the strided segment-boundary checkpoints in one batched pass at the end.
  ``eval_mode="inscan"`` keeps the legacy in-scan strides for bit-parity
  with ``make_runner``.

The driver accepts the device data path (in-scan store sampling) and the
host-streaming path (the :class:`~repro.data.device.StreamingSampler` chunk
stream is a pure function of ``(data_key, t)`` — segments re-gather their
rounds identically after a restart).  The legacy ``prestack`` path keeps
stateful host iterators and cannot resume mid-stream; it is rejected with a
pointer here.

See ``docs/robustness.md`` for the protocol details.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import load_checkpoint, save_checkpoint
from ..core.channel import CellConfig
from ..core.selection import as_policy_fn
from ..data.device import (StreamingSampler, data_stream_key,
                           from_client_datasets)
from ..data.synthetic import Dataset
from ..obs.taps import metrics_active
from ..obs.telemetry import (config_fingerprint, emit_run_manifest,
                             env_fingerprint, get_telemetry)
from ..optim import Optimizer, sgd
from .engine import (RoundTrace, SimConfig, SimResult, build_chunk_sim,
                     init_carry, resolve_data_path)

__all__ = ["run_resumable", "segment_bounds", "completed_segments",
           "read_segment_manifest"]


def segment_bounds(rounds: int, stride: int) -> list:
    """``[(t0, t1), ...]`` covering ``[0, rounds)`` in ``stride``-round
    segments (the last may be shorter)."""
    C = max(1, int(stride))
    return [(t0, min(t0 + C, rounds)) for t0 in range(0, rounds, C)]


def _fingerprint(cfg: SimConfig, num_clients: int, data_path: str) -> dict:
    """What must match for a resume to be sound: anything that changes the
    PRNG streams, shapes, or per-round math."""
    return {
        "rounds": cfg.rounds, "local_iters": cfg.local_iters,
        "batch_size": cfg.batch_size, "lr": cfg.lr, "seed": cfg.seed,
        "eval_every": cfg.eval_every, "eval_mode": cfg.eval_mode,
        "max_staleness": cfg.max_staleness, "aging_boost": cfg.aging_boost,
        "local_mode": cfg.local_mode, "data_stream": cfg.data_stream,
        "data_path": data_path, "num_clients": num_clients,
        "checkpoint_every": cfg.checkpoint_every,
        "faults": repr(cfg.faults), "guards": repr(cfg.guards),
        "metrics": repr(cfg.metrics),
    }


def _seg_base(ckpt_dir: str, i: int) -> str:
    return os.path.join(ckpt_dir, f"seg_{i:05d}")


def completed_segments(ckpt_dir: str, n_segments: int) -> int:
    """Number of leading segments with committed checkpoints (``.done``
    markers); a gap ends the count — later orphans are rerun."""
    n = 0
    for i in range(n_segments):
        if not os.path.exists(_seg_base(ckpt_dir, i) + ".done"):
            break
        n += 1
    return n


def _save_segment(ckpt_dir: str, i: int, carry, trace, meta: dict) -> None:
    base = _seg_base(ckpt_dir, i)
    save_checkpoint(base, carry, metadata=meta)
    np.savez(base + "_trace.npz",
             **{f: np.asarray(getattr(trace, f))
                for f in RoundTrace._fields})
    with open(base + ".done", "w") as f:
        f.write("ok")


def _load_trace(ckpt_dir: str, i: int) -> RoundTrace:
    data = np.load(_seg_base(ckpt_dir, i) + "_trace.npz")
    return RoundTrace(**{f: data[f] for f in RoundTrace._fields})


def _manifest_path(ckpt_dir: str) -> str:
    return os.path.join(ckpt_dir, "manifest.jsonl")


def _append_segment_manifest(ckpt_dir: str, entry: dict) -> None:
    with open(_manifest_path(ckpt_dir), "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def read_segment_manifest(ckpt_dir: str) -> list:
    """All segment-manifest entries recorded in ``ckpt_dir``, in append
    order.  A killed-and-resumed run leaves one entry per *executed*
    segment, so rerun segments appear twice — audit trails keep both."""
    path = _manifest_path(ckpt_dir)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def run_resumable(init_params: Any,
                  loss_fn: Callable,
                  acc_fn: Callable,
                  client_data: Sequence[Dataset],
                  test_ds: Dataset,
                  policy,
                  h_all: jax.Array,            # [K, rounds]
                  cell: CellConfig,
                  cfg: SimConfig,
                  ckpt_dir: str,
                  opt: Optimizer | None = None,
                  stop_after_segment: Optional[int] = None,
                  data_budget_bytes: int | None = None) -> SimResult | None:
    """Run (or continue) a checkpointed simulation; returns the usual
    :class:`~repro.fl.engine.SimResult`.

    ``stop_after_segment=n`` exits after committing ``n`` *new* segments and
    returns ``None`` — the test hook that simulates a mid-run kill; the next
    call with the same ``ckpt_dir`` picks up where it stopped.
    """
    K = len(client_data)
    T = cfg.rounds
    opt = opt or sgd(cfg.lr)
    policy_fn = as_policy_fn(policy)
    path = resolve_data_path(client_data, cfg, None, data_budget_bytes)
    if path == "prestack":
        raise ValueError(
            "the prestack data path consumes stateful host iterators and "
            "cannot resume mid-stream; use data_path='device' or 'stream' "
            "(both draw from stateless fold_in index streams)")
    bounds = segment_bounds(T, cfg.checkpoint_every or cfg.eval_every)
    os.makedirs(ckpt_dir, exist_ok=True)
    fp = _fingerprint(cfg, K, path)
    cfg_sha = config_fingerprint(cfg)
    env_fp = env_fingerprint()
    stride = cfg.checkpoint_every or cfg.eval_every
    emit_run_manifest("run_resumable", cfg,
                      extra={"path": path, "num_clients": K,
                             "ckpt_dir": ckpt_dir, "segments": len(bounds)})

    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    h_rounds = jnp.swapaxes(h_all, 0, 1)               # [T, K]
    key = jax.random.PRNGKey(cfg.seed)
    ts_full = jnp.arange(T, dtype=jnp.int32)

    raw = build_chunk_sim(loss_fn, acc_fn, opt, cfg, cell, K, policy_fn,
                          data_mode=("device" if path == "device"
                                     else "prestack"))
    chunk_fn = jax.jit(raw)
    pw_full = (jax.jit(jax.vmap(lambda t, h: policy_fn(t, h, None)))(
        ts_full, h_rounds) if raw.hoist
        else (jnp.zeros((T, 0)),) * 2)

    if path == "device":
        store = from_client_datasets(client_data)
        data_key = data_stream_key(cfg.seed)
        sampler = None
    else:
        sampler = StreamingSampler(client_data, data_stream_key(cfg.seed),
                                   cfg.local_iters, cfg.batch_size)

    # --- restore ------------------------------------------------------------
    done = completed_segments(ckpt_dir, len(bounds))
    like = init_carry(init_params, K, cfg)
    if done > 0:
        carry, meta = load_checkpoint(_seg_base(ckpt_dir, done - 1), like)
        if meta.get("fingerprint") != fp:
            raise ValueError(
                f"checkpoint directory {ckpt_dir!r} holds a different run "
                f"(saved {meta.get('fingerprint')} vs current {fp}); use a "
                "fresh directory or matching config")
        traces = [_load_trace(ckpt_dir, i) for i in range(done)]
    else:
        carry = like
        traces = []

    # --- run the remaining segments ----------------------------------------
    fresh = 0
    tel = get_telemetry()
    for i in range(done, len(bounds)):
        t0, t1 = bounds[i]
        pw_c = jax.tree_util.tree_map(lambda p: p[t0:t1], pw_full)
        t_start = time.perf_counter()
        with tel.span("resume.segment"):
            if path == "device":
                carry, tr = chunk_fn(carry, ts_full[t0:t1], h_rounds[t0:t1],
                                     pw_c, store, data_key, key,
                                     test_x, test_y)
            else:
                xb, yb = sampler.chunk(t0, t1)
                carry, tr = chunk_fn(carry, ts_full[t0:t1], h_rounds[t0:t1],
                                     xb, yb, pw_c, key, test_x, test_y)
            # _save_segment's np.asarray readback forces device sync, so the
            # wall time below covers execution, not just dispatch.
            _save_segment(ckpt_dir, i, carry, tr,
                          {"t0": t0, "t1": t1, "segment": i,
                           "fingerprint": fp})
        _append_segment_manifest(ckpt_dir, {
            "segment": i, "t0": t0, "t1": t1, "seed": cfg.seed,
            "stride": stride, "config_sha": cfg_sha, "fingerprint": env_fp,
            "wall_s": time.perf_counter() - t_start,
            "written_unix": time.time(),
        })
        traces.append(tr)
        fresh += 1
        if stop_after_segment is not None and fresh >= stop_after_segment \
                and i + 1 < len(bounds):
            return None                                # simulated kill

    state, energy = carry[0], carry[1]
    mstate = (carry[-1]
              if metrics_active(cfg.metrics, cfg.guards) else None)
    trace = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
        *traces)

    if cfg.eval_mode == "replay":
        return _replay_result(state, energy, trace, cfg, bounds, ckpt_dir,
                              like, loss_fn, acc_fn, test_x, test_y,
                              mstate=mstate)
    from .engine import _to_result
    return _to_result(state, energy, trace, cfg, mstate=mstate)


def _replay_result(state, energy, trace, cfg: SimConfig, bounds, ckpt_dir,
                   like, loss_fn, acc_fn, test_x, test_y,
                   mstate=None) -> SimResult:
    """Post-hoc strided evals: load every segment-boundary checkpoint's
    global params and evaluate them in one batched device call — the
    replacement for the in-scan ``lax.cond`` eval (which executes both
    branches every round under vmap)."""
    boundary_params = []
    for i in range(len(bounds)):
        carry_i, _ = load_checkpoint(_seg_base(ckpt_dir, i), like)
        boundary_params.append(carry_i[0].global_params)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
        *boundary_params)
    accs, losses = jax.jit(jax.vmap(
        lambda p: (jnp.asarray(acc_fn(p, test_x, test_y), jnp.float32),
                   jnp.asarray(loss_fn(p, test_x, test_y), jnp.float32))))(
        stacked)
    e_round = np.asarray(trace.e_round)
    faulty = cfg.faults is not None
    return SimResult(
        test_acc=np.asarray(accs),
        test_loss=np.asarray(losses),
        eval_rounds=np.asarray([t1 - 1 for _, t1 in bounds]),
        energy_per_client=np.asarray(energy),
        energy_timeline=np.cumsum(e_round.sum(axis=1)),
        participation=np.asarray(trace.mask),
        state=state,
        delivered=np.asarray(trace.delivered) if faulty else None,
        corrupted=np.asarray(trace.corrupt) if faulty else None,
        metrics=(jax.tree_util.tree_map(np.asarray, mstate)
                 if mstate is not None else None),
    )
