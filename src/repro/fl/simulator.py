"""Paper-faithful asynchronous-FL simulator (§II protocol, Fig. 1).

Per round t:
  1. every client runs ``local_iters`` SGD steps on its own shard
     (clients train continuously, regardless of participation);
  2. the server computes the round's policy (p_{k,t}, w_{k,t});
  3. each client independently draws Bernoulli(p_{k,t}) — optionally forced
     if its staleness exceeds its Δ_k bound;
  4. participants upload δ_k = x_k − y_k on their allocated sub-channel
     (energy ledger: P_k · S / R_{k,t});
  5. the server applies x ← x + (1/K)Σδ_k and broadcasts x to participants.

``run_simulation`` executes the whole horizon inside one ``lax.scan`` on
device (see :mod:`repro.fl.engine`); this module is the compatibility layer
that keeps the original signature.  ``run_simulation_legacy`` is the old
host-side round loop — same per-round helpers, same ``fold_in`` PRNG streams,
so the two agree bit-wise — kept for parity tests and the engine benchmark.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig
from ..core.selection import Policy, as_policy_fn
from ..data.device import (StreamingSampler, data_stream_key,
                           from_client_datasets, sample_round,
                           sample_round_client_stream)
from ..data.pipeline import BatchIterator, client_batches
from ..data.synthetic import Dataset
from ..obs.taps import (MetricsSpec, init_metrics, merge_metrics,
                        metrics_active, update_ledger_taps, update_train_taps)
from ..optim import Optimizer, sgd
from .engine import (SimConfig, SimResult, apply_round_decision,
                     empty_client_batches, make_local_train,
                     resolve_data_path, round_decision, run_simulation_scan)
from .faults import (FaultConfig, GuardConfig, apply_faults, corrupt_deltas,
                     init_fault_state)
from .state import (AggregatorConfig, FLState, broadcast_to_participants,
                    guarded_aggregate, init_fl_state, masked_aggregate,
                    pseudo_gradients, scheme_aggregate)

__all__ = ["SimConfig", "SimResult", "run_simulation",
           "run_simulation_legacy", "make_round_fn"]


def make_round_fn(loss_fn: Callable, opt: Optimizer, local_iters: int,
                  num_clients: int, local_mode: str = "continuous",
                  faults: FaultConfig | None = None,
                  guards: GuardConfig | None = None,
                  aggregator: AggregatorConfig | None = None,
                  metrics: MetricsSpec | None = None):
    """Build the jitted per-round transition over stacked client states.

    With faults/guards the transition takes the fault pipeline's extra
    operands — ``fl_round(state, mask, xb, yb, delivered, corrupt)`` — and
    applies the same corruption transform and defensive aggregation as the
    scan engine's round step (the legacy loop is the bit-parity witness for
    the robustness layer too).  With ``aggregator`` set the transition also
    takes the round's nominal policy ``probs`` and applies the pluggable
    scheme aggregation instead of the paper's 1/K averaging.

    When ``metrics`` enables any train tap the transition additionally takes
    the running :class:`~repro.obs.taps.MetricsState` and returns
    ``(state, metrics_state)`` instead of the bare state (static on the
    spec, so the untapped signature is unchanged).
    """
    vtrain = make_local_train(loss_fn, opt)
    fparams = faults.params() if faults is not None else None
    aparams = aggregator.params() if aggregator is not None else None
    ttap = metrics_active(metrics, guards, parts="train")

    @jax.jit
    def fl_round(state: FLState, mask: jax.Array, xb: jax.Array,
                 yb: jax.Array, delivered: jax.Array | None = None,
                 corrupt: jax.Array | None = None,
                 probs: jax.Array | None = None,
                 mstate=None) -> FLState:
        landed = mask if delivered is None else delivered
        client = vtrain(state.client_params, xb, yb)
        if local_mode == "participants":
            def keep(new, old):
                m = landed.reshape(
                    (-1,) + (1,) * (new.ndim - 1)).astype(bool)
                return jnp.where(m, new, old)

            client = jax.tree_util.tree_map(keep, client,
                                            state.client_params)
        state = state._replace(client_params=client)
        deltas = pseudo_gradients(state)
        if faults is not None and corrupt is not None:
            deltas = corrupt_deltas(deltas, corrupt, fparams, faults)
        if aggregator is not None:
            staleness = state.round - state.last_tx
            p = (jnp.zeros((num_clients,), jnp.float32) if probs is None
                 else probs)
            new_global = scheme_aggregate(state.global_params, deltas,
                                          landed, num_clients, staleness,
                                          p, aparams, guards=guards)
        elif guards is not None and guards.active:
            staleness = state.round - state.last_tx
            new_global = guarded_aggregate(state.global_params, deltas,
                                           landed, num_clients, staleness,
                                           guards)
        else:
            new_global = masked_aggregate(state.global_params, deltas,
                                          landed, num_clients)
        if ttap:
            p = (jnp.zeros((num_clients,), jnp.float32) if probs is None
                 else probs)
            ms = update_train_taps(
                mstate, metrics, deltas=deltas, delivered=landed,
                staleness=state.round - state.last_tx, probs=p,
                num_clients=num_clients, guards=guards, agg_params=aparams)
            return broadcast_to_participants(state, new_global, landed), ms
        return broadcast_to_participants(state, new_global, landed)

    return fl_round


def run_simulation(init_params: Any,
                   loss_fn: Callable,
                   acc_fn: Callable,
                   client_data: list[Dataset],
                   test_ds: Dataset,
                   policy: Policy,
                   h_all: jax.Array,           # [K, rounds] channel gains
                   cell: CellConfig,
                   cfg: SimConfig,
                   opt: Optimizer | None = None) -> SimResult:
    """One jitted ``lax.scan`` over all rounds (no per-round host sync)."""
    return run_simulation_scan(init_params, loss_fn, acc_fn, client_data,
                               test_ds, policy, h_all, cell, cfg, opt)


def run_simulation_legacy(init_params: Any,
                          loss_fn: Callable,
                          acc_fn: Callable,
                          client_data: list[Dataset],
                          test_ds: Dataset,
                          policy: Policy,
                          h_all: jax.Array,
                          cell: CellConfig,
                          cfg: SimConfig,
                          opt: Optimizer | None = None) -> SimResult:
    """Host-side round loop (the pre-scan engine).

    Each round syncs mask/energy through numpy and dispatches the jitted
    round transition separately — kept as the wall-clock baseline for
    ``benchmarks/bench_engine.py`` and as the reference in the scan-parity
    tests.  Decision logic, PRNG streams AND the data path are shared with
    the scan engine (``engine.round_decision`` with ``fold_in(seed, t)``;
    ``resolve_data_path`` picks the same minibatch source — device-store
    ``fold_in`` sampling by default, ``BatchIterator`` pre-stack streams
    when ``cfg.data_path == "prestack"``), so results match the scan engine
    bit-wise on identical configs.
    """
    K = len(client_data)
    opt = opt or sgd(cfg.lr)
    policy_fn = as_policy_fn(policy)
    state = init_fl_state(init_params, K)
    round_fn = make_round_fn(loss_fn, opt, cfg.local_iters, K,
                             local_mode=cfg.local_mode, faults=cfg.faults,
                             guards=cfg.guards, aggregator=cfg.aggregator,
                             metrics=cfg.metrics)
    base_key = jax.random.PRNGKey(cfg.seed)

    # metrics taps: the ledger half accumulates host-side via its own jitted
    # update (same full-[K] vector ops as the scan engines — bit-identical
    # integer counters); the train half rides through fl_round
    ltap = metrics_active(cfg.metrics, None, parts="ledger")
    ttap = metrics_active(cfg.metrics, cfg.guards, parts="train")
    ms_l = init_metrics(cfg.metrics, K, None, parts="ledger")
    ms_t = init_metrics(cfg.metrics, K, cfg.guards, parts="train")
    if ltap:
        ledger_tap = jax.jit(lambda ms, m, f, eb, er, st, d:
                             update_ledger_taps(ms, cfg.metrics, mask=m,
                                                forced=f, e_base=eb,
                                                e_round=er, staleness=st,
                                                delivered=d))

    # split the policy eval from the decision so the nominal probs (pre
    # aging-boost) are available to scheme aggregation — mask/forced/w/e
    # stay bit-identical to round_decision (which composes the same pair)
    def _decide(t, h_t, st):
        probs, w = policy_fn(t, h_t, st)
        mask, forced, w, e_round = apply_round_decision(
            probs, w, t, h_t, st, base_key, cfg, cell, K)
        return probs, mask, forced, w, e_round

    decide = jax.jit(_decide)

    # fault pipeline: same salted fold_in streams as the scan engine, so the
    # two realize identical faults round for round
    if cfg.faults is not None:
        fstate = init_fault_state(K)
        fparams = cfg.faults.params()
        fault_step = jax.jit(lambda t, m, e, fs: apply_faults(
            t, base_key, m, e, fs, fparams, cfg.faults))

    data_path = resolve_data_path(client_data, cfg)
    data_key = data_stream_key(cfg.seed)
    if data_path == "prestack":
        iters = [BatchIterator(ds, cfg.batch_size, seed=cfg.seed + 17 * k)
                 for k, ds in enumerate(client_data)]
    elif data_path == "device":  # per-round jitted draw from the store
        store = from_client_datasets(client_data)
        draw = (sample_round_client_stream if cfg.data_stream == "client"
                else sample_round)
        sample = jax.jit(lambda t: draw(
            store, data_key, t, cfg.local_iters, cfg.batch_size))
    else:  # stream: data stays host-side (it was chosen because the store
        # does not fit on device); same index stream, one-round chunks
        sampler = StreamingSampler(client_data, data_key, cfg.local_iters,
                                   cfg.batch_size)
        sample = lambda t: tuple(c[0] for c in  # noqa: E731
                                 sampler.chunk(int(t), int(t) + 1))

    energy = np.zeros((K,), np.float32)
    energy_tl = np.zeros((cfg.rounds,))
    parts = np.zeros((cfg.rounds, K), np.float32)
    delivered_tl = np.zeros((cfg.rounds, K), np.float32)
    corrupt_tl = np.zeros((cfg.rounds, K), np.float32)
    accs, losses, eval_rounds = [], [], []

    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    eval_fn = jax.jit(lambda p: (acc_fn(p, test_x, test_y),
                                 loss_fn(p, test_x, test_y)))

    if data_path == "prestack" and cfg.local_iters == 0:
        empty_x, empty_y = empty_client_batches(client_data, cfg)

    for t in range(cfg.rounds):
        # --- per-round batches; each data path must draw exactly what the
        # scan engine consumes at round t (prestack: iterator seeds and
        # consumption order match stack_round_batches; device: the shared
        # fold_in(data_key, t) store stream) or scan-parity breaks ---------
        if data_path != "prestack":
            xb, yb = sample(jnp.int32(t))
        elif cfg.local_iters == 0:
            xb, yb = empty_x, empty_y
        else:
            xs, ys = [], []
            for _ in range(cfg.local_iters):
                xb, yb = client_batches(iters)
                xs.append(xb)
                ys.append(yb)
            xb = jnp.stack(xs, axis=1)  # [K, local_iters, B, ...]
            yb = jnp.stack(ys, axis=1)

        # --- policy + autonomous decisions + energy ledger (eq. 5) ---------
        probs, mask, forced, w, e_round = decide(jnp.int32(t), h_all[:, t],
                                                 state)
        e_base = e_round     # decision energy before the fault pipeline
        # --- fault pipeline (availability → crash → lossy uplink) ----------
        if cfg.faults is not None:
            out, fstate = fault_step(jnp.int32(t), mask, e_round, fstate)
            delivered, corrupt, e_round = (out.delivered, out.corrupt,
                                           out.e_round)
            delivered_tl[t] = np.asarray(delivered)
            corrupt_tl[t] = np.asarray(corrupt)
        else:
            delivered, corrupt = None, None
        energy += np.asarray(e_round)
        energy_tl[t] = energy.sum()
        parts[t] = np.asarray(mask)
        if ltap:
            ms_l = ledger_tap(ms_l, mask, forced, e_base, e_round,
                              state.round - state.last_tx,
                              mask if delivered is None else delivered)

        # --- one protocol round --------------------------------------------
        if ttap:
            state, ms_t = round_fn(state, mask, xb, yb, delivered, corrupt,
                                   probs, ms_t)
        else:
            state = round_fn(state, mask, xb, yb, delivered, corrupt, probs)

        if t % cfg.eval_every == 0 or t == cfg.rounds - 1:
            a, l = eval_fn(state.global_params)
            accs.append(float(a))
            losses.append(float(l))
            eval_rounds.append(t)

    faulty = cfg.faults is not None
    ms = merge_metrics(ms_l, ms_t)
    return SimResult(np.asarray(accs), np.asarray(losses),
                     np.asarray(eval_rounds), energy, energy_tl, parts, state,
                     delivered=delivered_tl if faulty else None,
                     corrupted=corrupt_tl if faulty else None,
                     metrics=(jax.tree_util.tree_map(np.asarray, ms)
                              if ms is not None else None))
