"""Paper-faithful asynchronous-FL simulator (§II protocol, Fig. 1).

Per round t:
  1. every client runs ``local_iters`` SGD steps on its own shard
     (clients train continuously, regardless of participation);
  2. the server computes the round's policy (p_{k,t}, w_{k,t});
  3. each client independently draws Bernoulli(p_{k,t}) — optionally forced
     if its staleness exceeds its Δ_k bound;
  4. participants upload δ_k = x_k − y_k on their allocated sub-channel
     (energy ledger: P_k · S / R_{k,t});
  5. the server applies x ← x + (1/K)Σδ_k and broadcasts x to participants.

The per-round compute is one jitted function over stacked client states.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig, rate_nats
from ..core.selection import Policy, realize
from ..data.pipeline import BatchIterator, client_batches
from ..data.synthetic import Dataset
from ..optim import Optimizer, sgd
from .state import (FLState, broadcast_to_participants, init_fl_state,
                    masked_aggregate, pseudo_gradients)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    rounds: int = 50
    local_iters: int = 5          # paper: 5 for MNIST, 1 for CIFAR
    batch_size: int = 10          # paper: 10 for MNIST, 128 for CIFAR
    lr: float = 0.01              # paper: 0.01
    eval_every: int = 5
    seed: int = 0
    max_staleness: int | None = None   # Δ_k enforcement (None = pure Bernoulli)
    aging_boost: bool = False          # beyond-paper: soft aging — raise p as
                                       # staleness → Δ_k so clients transmit at
                                       # the first decent fade *before* the
                                       # deadline forces a deep-fade upload
    eval_batch: int = 2048


class SimResult(NamedTuple):
    test_acc: np.ndarray        # [n_evals]
    test_loss: np.ndarray       # [n_evals]
    eval_rounds: np.ndarray     # [n_evals]
    energy_per_client: np.ndarray  # [K] cumulative Joules
    energy_timeline: np.ndarray    # [rounds] cumulative total energy
    participation: np.ndarray      # [rounds, K] realized masks
    state: FLState


def make_round_fn(loss_fn: Callable, opt: Optimizer, local_iters: int,
                  num_clients: int):
    """Build the jitted per-round transition over stacked client states."""

    def local_train(params, xb, yb):
        # xb: [local_iters, B, ...] for one client
        opt_state = opt.init(params)

        def one(carry, batch):
            params, opt_state = carry
            x, y = batch
            g = jax.grad(loss_fn)(params, x, y)
            upd, opt_state = opt.update(g, opt_state, params)
            params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
            return (params, opt_state), None

        (params, _), _ = jax.lax.scan(one, (params, opt_state), (xb, yb))
        return params

    vtrain = jax.vmap(local_train)

    @jax.jit
    def fl_round(state: FLState, mask: jax.Array, xb: jax.Array,
                 yb: jax.Array) -> FLState:
        client = vtrain(state.client_params, xb, yb)
        state = state._replace(client_params=client)
        deltas = pseudo_gradients(state)
        new_global = masked_aggregate(state.global_params, deltas, mask,
                                      num_clients)
        return broadcast_to_participants(state, new_global, mask)

    return fl_round


def run_simulation(init_params: Any,
                   loss_fn: Callable,
                   acc_fn: Callable,
                   client_data: list[Dataset],
                   test_ds: Dataset,
                   policy: Policy,
                   h_all: jax.Array,           # [K, rounds] channel gains
                   cell: CellConfig,
                   cfg: SimConfig,
                   opt: Optimizer | None = None) -> SimResult:
    K = len(client_data)
    opt = opt or sgd(cfg.lr)
    state = init_fl_state(init_params, K)
    round_fn = make_round_fn(loss_fn, opt, cfg.local_iters, K)

    iters = [BatchIterator(ds, cfg.batch_size, seed=cfg.seed + 17 * k)
             for k, ds in enumerate(client_data)]
    key = jax.random.PRNGKey(cfg.seed)

    energy = np.zeros((K,))
    energy_tl = np.zeros((cfg.rounds,))
    parts = np.zeros((cfg.rounds, K), np.float32)
    accs, losses, eval_rounds = [], [], []

    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    eval_fn = jax.jit(lambda p: (acc_fn(p, test_x, test_y),
                                 loss_fn(p, test_x, test_y)))

    for t in range(cfg.rounds):
        # --- stack local_iters batches per client --------------------------
        xs, ys = [], []
        for _ in range(cfg.local_iters):
            xb, yb = client_batches(iters)
            xs.append(xb)
            ys.append(yb)
        xb = jnp.stack(xs, axis=1)  # [K, local_iters, B, ...]
        yb = jnp.stack(ys, axis=1)

        # --- server policy + autonomous client decisions --------------------
        h_t = h_all[:, t]
        dec = policy.decide(t, h_t)
        if cfg.aging_boost and cfg.max_staleness is not None:
            staleness = (int(state.round) - np.asarray(state.last_tx))
            boost = np.clip(staleness / cfg.max_staleness, 0.0, 1.0) ** 2
            probs = 1.0 - (1.0 - np.asarray(dec.probs)) * (1.0 - boost)
            dec = type(dec)(probs=jnp.asarray(probs, jnp.float32), w=dec.w)
        key, sub = jax.random.split(key)
        mask = realize(sub, dec)
        forced = np.zeros((K,), bool)
        if cfg.max_staleness is not None:
            stale = (int(state.round) - np.asarray(state.last_tx)
                     >= cfg.max_staleness)
            forced = stale & (np.asarray(mask) == 0)
            mask = jnp.maximum(mask, jnp.asarray(stale, jnp.float32))

        # --- energy ledger (realized transmissions, eq. 5) ------------------
        m = np.asarray(mask)
        w = np.asarray(dec.w)
        if forced.any():
            # staleness-aware bandwidth reservation (beyond-paper): a client
            # transmitting only because its Δ_k bound expired would otherwise
            # use its (near-floor) probabilistic slice — grant it an equal
            # 1/K share and rescale so Σw ≤ 1
            w = np.where(forced, np.maximum(w, 1.0 / K), w)
            tot = w[m > 0].sum() + w[m == 0].sum() * 0.0
            if w.sum() > 1.0:
                w = w / w.sum()
        R = np.asarray(rate_nats(jnp.asarray(w), h_t, cell.tx_power_w,
                                 cell.bandwidth_hz, cell.noise_w_per_hz))
        e_round = m * cell.tx_power_w * cell.model_size_nats / np.maximum(R, 1e-30)
        e_round = np.where(m > 0, e_round, 0.0)
        energy += e_round
        energy_tl[t] = energy.sum()
        parts[t] = m

        # --- one protocol round ---------------------------------------------
        state = round_fn(state, mask, xb, yb)

        if t % cfg.eval_every == 0 or t == cfg.rounds - 1:
            a, l = eval_fn(state.global_params)
            accs.append(float(a))
            losses.append(float(l))
            eval_rounds.append(t)

    return SimResult(np.asarray(accs), np.asarray(losses),
                     np.asarray(eval_rounds), energy, energy_tl, parts, state)
