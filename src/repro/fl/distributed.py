"""Mega-scale FL train steps for the assigned architectures.

Two modes (DESIGN.md §Arch-applicability):

* **replica** (paper-faithful): per-virtual-client divergent params x_k and
  anchors y_k, stacked on a leading K axis that shards over the mesh's
  data-parallel axes.  ``vmap`` over the client axis gives per-client-weights
  forward/backward; the masked pseudo-gradient aggregation (eq. 3) is an
  einsum over K.  Fits archs ≤ ~34B total params on the 256-chip pod.

* **masked-dp** (scalable adaptation for jamba-398B / llama4-400B): a single
  FSDP-sharded global model; each round the Bernoulli participation mask m_k
  gates which data groups contribute, importance-weighted m_k/p_k so the
  aggregated gradient is unbiased.  The paper's probability/bandwidth
  optimization applies unchanged; continuous local divergence is foregone.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..data.device import DeviceDataStore, sample_batch
from ..models import transformer as T


class DistFLState(NamedTuple):
    global_params: Any
    client_params: Any   # [K, ...] stacked (replica mode) or None
    anchor_params: Any   # [K, ...] stacked (replica mode) or None


def mode_for(cfg: ArchConfig, hbm_budget_bytes: float = 3.2e12) -> str:
    """replica if 2·K·P fits comfortably in pod HBM, else masked-dp."""
    n = param_count(cfg)
    bytes_needed = 2 * 16 * n * 2  # 2 copies × K=16 × bf16
    return "replica" if bytes_needed < hbm_budget_bytes else "masked_dp"


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (matches init_params leaf sum)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    total = V * d + d  # embed + final norm
    if not cfg.tie_embeddings:
        total += d * V
    import math
    di = cfg.ssm_expand * d
    dtr = max(1, math.ceil(d / 16))
    N, k = cfg.ssm_state, cfg.ssm_conv
    for li in range(cfg.n_layers):
        mixer = cfg.mixer_pattern[li % len(cfg.mixer_pattern)]
        total += d  # ln1
        if mixer == "attn":
            total += d * H * hd + 2 * d * KV * hd + H * hd * d
            if cfg.qk_norm:
                total += 2 * hd
        elif mixer == "mamba":
            total += (d * 2 * di + k * di + di + di * (dtr + 2 * N)
                      + dtr * di + di + di * N + di + di * d)
        elif mixer == "mlstm":
            total += 5 * d * d + 2 * d * H  # q,k,v,o-gate,out + i/f gates
        elif mixer == "slstm":
            total += 4 * d * d + 4 * (d // H) * d + 4 * d + d * d
        kind = cfg.ffn_kind(li)
        if kind != "none":
            total += d  # ln2
        if kind == "dense":
            total += 3 * d * ff
        elif kind == "moe":
            m = cfg.moe
            total += d * m.num_experts + 3 * m.num_experts * d * m.d_ff_expert
    return int(total)


def init_dist_state(key, cfg: ArchConfig, num_clients: int,
                    mode: str = "replica") -> DistFLState:
    params = T.init_params(key, cfg)
    if mode == "masked_dp":
        return DistFLState(global_params=params, client_params=None,
                           anchor_params=None)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (num_clients,) + p.shape),
        params)
    return DistFLState(global_params=params, client_params=stacked,
                       anchor_params=stacked)


def _client_loss(cfg: ArchConfig):
    def f(params, batch):
        return T.loss(params, cfg, batch)
    return f


@partial(jax.jit, static_argnames=("cfg", "local_iters", "micro_batches"))
def fl_train_step(state: DistFLState, cfg: ArchConfig, batch: Any,
                  mask: jax.Array, lr: float, local_iters: int = 1,
                  micro_batches: int = 1) -> tuple[DistFLState, dict]:
    """One paper round in replica mode.

    batch: pytree with leading [K, B, ...]; mask: [K] 0/1 Bernoulli draws of
    the server-optimized probabilities.  ``micro_batches`` splits each
    client's batch into sequential gradient-accumulation chunks (§Perf:
    divides activation memory by the chunk count at identical math — the
    lever that brings 34B replica-mode training under the 16 GB/chip HBM).
    """
    K = mask.shape[0]
    loss_fn = _client_loss(cfg)

    def grad_accum(params, b):
        if micro_batches == 1:
            return jax.value_and_grad(loss_fn)(params, b)
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((micro_batches, x.shape[0] // micro_batches)
                                + x.shape[1:]), b)

        def one_micro(carry, bm):
            l_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, bm)
            g_acc = jax.tree_util.tree_map(
                lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
            return (l_acc + l, g_acc), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (l_sum, g_sum), _ = jax.lax.scan(one_micro, (jnp.zeros(()), zeros),
                                         mb)
        inv = 1.0 / micro_batches
        return l_sum * inv, jax.tree_util.tree_map(lambda g: g * inv, g_sum)

    def local(params, b):
        def one(params, _):
            l, g = grad_accum(params, b)
            params = jax.tree_util.tree_map(
                lambda p, gg: p - lr * gg.astype(p.dtype), params, g)
            return params, l
        params, ls = jax.lax.scan(one, params, None, length=local_iters)
        return params, ls.mean()

    client, losses = jax.vmap(local)(state.client_params, batch)

    # eq. (2)/(3): masked pseudo-gradient aggregation.  Deltas stay in the
    # param dtype (bf16 transport of pseudo-gradients — the wireless uplink
    # analogue); the K-reduction accumulates in fp32 (§Perf iteration 6:
    # halves the aggregation temps vs fp32 delta materialization).
    def agg(g, c, a):
        m = mask.astype(c.dtype).reshape((-1,) + (1,) * (g.ndim))
        delta = (c - a) * m
        s = jnp.sum(delta.astype(jnp.float32), axis=0)
        return (g.astype(jnp.float32) + s / K).astype(g.dtype)

    new_global = jax.tree_util.tree_map(agg, state.global_params, client,
                                        state.anchor_params)

    # broadcast to participants only (protocol step 5)
    def sel(stacked, g):
        m = mask.reshape((-1,) + (1,) * g.ndim).astype(bool)
        return jnp.where(m, g[None].astype(stacked.dtype), stacked)

    client = jax.tree_util.tree_map(sel, client, new_global)
    anchor = jax.tree_util.tree_map(sel, state.anchor_params, new_global)
    metrics = {"loss": losses.mean(), "participants": mask.sum()}
    return DistFLState(new_global, client, anchor), metrics


@partial(jax.jit, static_argnames=("cfg", "batch_size", "local_iters",
                                   "micro_batches"))
def fl_train_step_from_store(state: DistFLState, cfg: ArchConfig,
                             store: DeviceDataStore, data_key: jax.Array,
                             t: jax.Array, mask: jax.Array, lr: float,
                             batch_size: int, local_iters: int = 1,
                             micro_batches: int = 1) -> tuple[DistFLState,
                                                              dict]:
    """Replica-mode round fed from a :class:`DeviceDataStore`.

    The round's ``[K, B, S]`` token batch is gathered on device from the
    ``fold_in(data_key, t)`` stream and fused into the same jitted program
    as the train step — no per-round host stacking, and peak data memory is
    the store itself (independent of the horizon).  This is the mega-arch
    analogue of the scan engine's device data path.
    """
    toks, _ = sample_batch(store, data_key, t, batch_size)
    return fl_train_step(state, cfg, {"tokens": toks}, mask, lr,
                         local_iters=local_iters,
                         micro_batches=micro_batches)


@partial(jax.jit, static_argnames=("cfg",))
def fl_train_step_masked_dp(state: DistFLState, cfg: ArchConfig, batch: Any,
                            mask: jax.Array, probs: jax.Array,
                            lr: float) -> tuple[DistFLState, dict]:
    """One round in masked-DP mode: unbiased inverse-probability weighting.

    E[ (1/K) Σ (m_k/p_k) g_k ] = (1/K) Σ g_k — the synchronous-FL gradient.

    The aggregate is computed as the gradient of the *weighted scalar loss*
    L = (1/K) Σ_k (m_k/p_k)·loss_k — a single backward pass whose gradient
    IS the masked aggregate, so per-client gradients (K × P floats) are
    never materialized.
    """
    K = mask.shape[0]
    loss_fn = _client_loss(cfg)
    wgt = (mask / jnp.maximum(probs, 1e-6)).astype(jnp.float32)

    def weighted_loss(params):
        losses = jax.vmap(lambda b: loss_fn(params, b))(batch)
        return jnp.sum(losses * wgt) / K, losses

    (_, losses), grad = jax.value_and_grad(weighted_loss,
                                           has_aux=True)(state.global_params)

    new_global = jax.tree_util.tree_map(
        lambda g, gg: (g.astype(jnp.float32)
                       - lr * gg.astype(jnp.float32)).astype(g.dtype),
        state.global_params, grad)
    metrics = {"loss": losses.mean(), "participants": mask.sum()}
    return DistFLState(new_global, None, None), metrics
