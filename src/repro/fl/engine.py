"""On-device scan-based FL simulation engine.

The paper's per-round protocol (§II, Fig. 1) — policy, autonomous Bernoulli
participation, Δ_k forced transmission, bandwidth reservation, energy ledger
(eq. 5), local SGD, masked aggregation (eq. 3), broadcast — is expressed as a
single jittable round transition and executed for all ``T`` rounds inside one
``lax.scan``.  Nothing syncs to the host per round; the only readback is the
stacked per-round trace at the end (masks, energies, strided evals).

Layout:

* **policy interface** — a pure ``PolicyFn`` ``(t, h_t, sim_state) ->
  (probs, w)`` (see :mod:`repro.core.selection`); legacy ``Policy`` objects
  are coerced via ``as_policy_fn``.
* **scan carry** — ``(FLState, energy [K] f32)``: global model, stacked client
  models/anchors, round counter, per-client last-transmission round, and the
  cumulative per-client energy ledger, all device arrays.
* **per-round PRNG** — ``jax.random.fold_in(base_key, t)``: the stream only
  depends on ``(seed, t)``, so the host loop and the scan engine draw
  bit-identical participation masks (the parity tests rely on this).
* **evals** — computed inside the scan at ``eval_every`` strides via
  ``lax.cond`` (off-stride rounds skip the forward pass when not vmapped).
* **scenario fan-out** — ``run_scenario_matrix`` vmaps the whole simulation
  over ρ (the tradeoff coefficient of (P1'), traced through ``solve_online``)
  × scenario lanes (channel realizations + PRNG seeds) in one device program;
  ``run_seed_matrix`` does the lane axis for arbitrary policies.

See ``docs/engine.md`` for the full architecture notes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig, rate_nats
from ..core.selection import PolicyFn, as_policy_fn, online_policy
from ..data.device import (StreamingSampler, choose_data_path,
                           data_stream_key, from_client_datasets,
                           sample_round, sample_round_client_stream)
from ..data.pipeline import BatchIterator, client_batches
from ..data.synthetic import Dataset
from ..obs.taps import (MetricsSpec, init_metrics, metrics_active,
                        metrics_round_update)
from ..obs.telemetry import emit_run_manifest, get_telemetry
from ..optim import Optimizer, sgd
from .faults import (FaultConfig, FaultState, GuardConfig, apply_faults,
                     corrupt_deltas, init_fault_state)
from .state import (AggregatorConfig, FLState, broadcast_to_participants,
                    guarded_aggregate, init_fl_state, masked_aggregate,
                    pseudo_gradients, scheme_aggregate)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    rounds: int = 50
    local_iters: int = 5          # paper: 5 for MNIST, 1 for CIFAR
    batch_size: int = 10          # paper: 10 for MNIST, 128 for CIFAR
    lr: float = 0.01              # paper: 0.01
    eval_every: int = 5
    seed: int = 0
    max_staleness: int | None = None   # Δ_k enforcement (None = pure Bernoulli)
    aging_boost: bool = False          # beyond-paper: soft aging — raise p as
                                       # staleness → Δ_k so clients transmit at
                                       # the first decent fade *before* the
                                       # deadline forces a deep-fade upload
    eval_batch: int = 2048
    # data path: "auto" picks "device" (DeviceDataStore + in-scan sampling)
    # when the padded store fits the memory budget, else "stream" (host
    # blocks, double-buffered round-chunk prefetch).  "prestack" is the
    # legacy [T, K, L, B] pre-stack, kept as the parity/benchmark reference.
    data_path: str = "auto"
    stream_chunk: int = 256            # rounds per streamed chunk
    # local-training semantics: "continuous" (paper default — every client
    # runs local SGD every round, cost irreducibly O(K·T)) or "participants"
    # (only the transmitting set trains, from its last received global — the
    # sampled-FedAvg reading; what the sparse path accelerates).
    local_mode: str = "continuous"
    # round execution: "dense" ([K]-shaped round transition), "sparse"
    # (participant-centric two-phase path, see repro.fl.sparse), or "auto"
    # (sparse exactly when its preconditions hold — participants local mode,
    # state_free policy, device data path, per-client stream).
    participation: str = "dense"
    participant_bucket: int | None = None  # static padded transmitting-set
                                           # size (None = auto from E[Σp])
    # minibatch index stream: "round" draws one [K, L, B] block per round
    # from fold_in(data_key, t); "client" keys each client's draw separately
    # (fold_in(fold_in(data_key, t), k)) so a participant's batch can be
    # sampled without touching the other K-1 clients (sparse path needs it).
    data_stream: str = "round"
    # --- robustness layer (docs/robustness.md) -----------------------------
    # fault injection: None leaves the engine's program byte-for-byte
    # unchanged (the bit-parity guarantee); a FaultConfig threads jittable
    # availability/crash/uplink-loss/corruption processes through the scan.
    faults: FaultConfig | None = None
    # defensive aggregation: None (or an all-off GuardConfig) is
    # bit-identical to the plain eq.-3 update; otherwise non-finite
    # quarantine, norm clipping and staleness down-weighting apply.
    guards: GuardConfig | None = None
    # aggregation scheme: None keeps the paper's eq.-3 update on the exact
    # legacy code path (the byte-for-byte bit-parity guarantee); an
    # AggregatorConfig routes through the pluggable weighted path —
    # FedAsync-style s(Δτ) mixing, CSMAAFL importance weighting, or
    # Hu–Chen–Larsson age-aware weighting (docs/schemes.md).  Guards
    # compose with any scheme.
    aggregator: AggregatorConfig | None = None
    # eval placement: "inscan" evaluates at eval_every strides via lax.cond
    # inside the scan (both branches execute under vmap); "replay" skips
    # in-scan evals entirely — the resumable driver evaluates its strided
    # param checkpoints post-hoc in one batched pass (fl/resume.py).
    eval_mode: str = "inscan"
    # resumable execution: segment length for fl.resume.run_resumable (the
    # checkpoint stride); None = eval_every.
    checkpoint_every: int | None = None
    # sparse participant_bucket overflow handling: "spill" regrows the
    # bucket toward the dense width and reruns (warn once), "error" keeps
    # the legacy hard RuntimeError.
    overflow: str = "spill"
    # in-scan metrics taps (docs/observability.md): None (default) adds
    # nothing to any carry or program — the bit-parity guarantee; a
    # MetricsSpec threads fixed-shape accumulators (participation counts,
    # staleness histogram, energy by cause, guard events, weight stats)
    # through the scan carry and returns them on SimResult.metrics.
    metrics: MetricsSpec | None = None


class SimResult(NamedTuple):
    test_acc: np.ndarray        # [n_evals]
    test_loss: np.ndarray       # [n_evals]
    eval_rounds: np.ndarray     # [n_evals]
    energy_per_client: np.ndarray  # [K] cumulative Joules
    energy_timeline: np.ndarray    # [rounds] cumulative total energy
    participation: np.ndarray      # [rounds, K] realized decision masks
    state: FLState
    # fault-injection extras (None on clean runs — the legacy 7-field
    # contract is unchanged): what actually landed at the server after
    # availability/crash/uplink-loss, and which deliveries were corrupted.
    delivered: np.ndarray | None = None   # [rounds, K]
    corrupted: np.ndarray | None = None   # [rounds, K]
    # in-scan metrics accumulators (None unless cfg.metrics enables taps);
    # a repro.obs.taps.MetricsState of numpy arrays — feed metrics_summary.
    metrics: Any = None


class RoundTrace(NamedTuple):
    """Per-round scan outputs (leading axis T after the scan).

    ``delivered``/``corrupt`` mirror ``mask`` when faults are disabled (the
    fault pipeline is not even traced then — they are aliases of ``mask`` /
    zeros, adding nothing to the program).
    """

    mask: jax.Array      # [K] realized participation (the decision)
    e_round: jax.Array   # [K] Joules spent this round (incl. retry cost)
    acc: jax.Array       # scalar (0 when did_eval is False)
    loss: jax.Array      # scalar (0 when did_eval is False)
    did_eval: jax.Array  # bool scalar
    delivered: jax.Array  # [K] updates that actually landed at the server
    corrupt: jax.Array    # [K] bool — delivered but adversarially poisoned


# ---------------------------------------------------------------------------
# shared per-round pieces (scan engine AND legacy host loop use these, so the
# two execution modes agree bit-wise on identical PRNG streams)
# ---------------------------------------------------------------------------


def grant_forced_bandwidth(w: jax.Array, forced: jax.Array,
                           num_clients: int) -> jax.Array:
    """Staleness-aware bandwidth reservation (beyond-paper), corrected.

    A client transmitting only because its Δ_k bound expired would otherwise
    use its (possibly zero) probabilistic slice — grant it an equal 1/K
    share.  When Σw ≤ 1 still holds after granting, non-forced clients keep
    their server-optimal allocation untouched (the old implementation
    renormalized everyone, shrinking optimal slices too).  Only when the
    grant overflows the band do non-forced clients shrink, proportionally,
    into the remaining room — the forced grant is never scaled to zero
    (policies like greedy/age allocate w = 0 to unselected clients, so
    "rescale the granted shares into the leftover slack" would strand a
    forced client at w = 0 and blow up its eq.-5 energy).  Branch-free:
    with no forced client this is the identity.
    """
    forced_f = forced.astype(w.dtype)
    granted = jnp.where(forced, jnp.maximum(w, 1.0 / num_clients), w)
    g = jnp.sum(granted * forced_f)            # requested forced mass
    b = jnp.sum(w * (1.0 - forced_f))          # non-forced (optimal) mass
    # forced keep their grant, capped at the full band
    g_scale = jnp.where(g > 1.0, 1.0 / jnp.maximum(g, 1e-30), 1.0)
    room = 1.0 - jnp.minimum(g, 1.0)
    # non-forced shrink only when the grant leaves too little room
    nf_scale = jnp.where(b > room, room / jnp.maximum(b, 1e-30), 1.0)
    return jnp.where(forced, granted * g_scale, w * nf_scale)


def apply_round_decision(probs: jax.Array, w: jax.Array, t: jax.Array,
                         h_t: jax.Array, state: FLState, base_key: jax.Array,
                         cfg: SimConfig, cell: CellConfig, num_clients: int):
    """Protocol Steps 3-4 + energy ledger given the round's (probs, w).

    Returns ``(mask, forced, w, e_round)``; the PRNG stream is
    ``fold_in(base_key, t)`` so it only depends on ``(seed, t)``.
    """
    K = num_clients
    probs = probs.astype(jnp.float32)
    w = w.astype(jnp.float32)
    staleness = (state.round - state.last_tx).astype(jnp.float32)
    if cfg.aging_boost and cfg.max_staleness is not None:
        boost = jnp.clip(staleness / cfg.max_staleness, 0.0, 1.0) ** 2
        probs = 1.0 - (1.0 - probs) * (1.0 - boost)
    u = jax.random.uniform(jax.random.fold_in(base_key, t), (K,))
    mask = (u < probs).astype(jnp.float32)
    forced = jnp.zeros((K,), bool)
    if cfg.max_staleness is not None:
        stale = (state.round - state.last_tx) >= cfg.max_staleness
        forced = stale & (mask == 0.0)
        mask = jnp.maximum(mask, stale.astype(jnp.float32))
        w = grant_forced_bandwidth(w, forced, K)
    R = rate_nats(w, h_t, cell.tx_power_w, cell.bandwidth_hz,
                  cell.noise_w_per_hz)
    e_round = mask * cell.tx_power_w * cell.model_size_nats \
        / jnp.maximum(R, 1e-30)
    e_round = jnp.where(mask > 0.0, e_round, 0.0)
    return mask, forced, w, e_round


def round_decision(policy_fn: PolicyFn, t: jax.Array, h_t: jax.Array,
                   state: FLState, base_key: jax.Array, cfg: SimConfig,
                   cell: CellConfig, num_clients: int):
    """Protocol Steps 2-4 for one round: policy then
    :func:`apply_round_decision` (the legacy host loop's per-round path)."""
    probs, w = policy_fn(t, h_t, state)
    return apply_round_decision(probs, w, t, h_t, state, base_key, cfg, cell,
                                num_clients)


def make_local_train(loss_fn: Callable, opt: Optimizer):
    """vmapped-over-clients local SGD: ``(params, xb, yb) -> params`` with
    ``xb: [K, local_iters, B, ...]``."""

    def local_train(params, xb, yb):
        opt_state = opt.init(params)

        def one(carry, batch):
            params, opt_state = carry
            x, y = batch
            g = jax.grad(loss_fn)(params, x, y)
            upd, opt_state = opt.update(g, opt_state, params)
            params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
            return (params, opt_state), None

        (params, _), _ = jax.lax.scan(one, (params, opt_state), (xb, yb))
        return params

    return jax.vmap(local_train)


def empty_client_batches(client_data: Sequence[Dataset], cfg: SimConfig):
    """``[K, 0, B, ...]`` placeholder pair for protocol-only runs
    (``local_iters=0``): the training scan is a no-op, clients never move."""
    K = len(client_data)
    sample = client_data[0].x.shape[1:]
    b = min(cfg.batch_size, min(len(c.y) for c in client_data))
    return (jnp.zeros((K, 0, b) + tuple(sample)),
            jnp.zeros((K, 0, b), jnp.int32))


def stack_round_batches(client_data: Sequence[Dataset], cfg: SimConfig):
    """Pre-draw every round's batches with the legacy iterator streams.

    Returns ``(xb_all, yb_all)`` shaped ``[T, K, local_iters, B, ...]`` —
    consumption order (round-major, local-iter-minor, per-client seeded
    ``cfg.seed + 17k``) matches the host loop exactly, so both engines train
    on identical data.  MNIST-scale footprint: T·K·L·B·784 fp32 ≈ 125 MB at
    (T=50, K=16, L=5, B=10); for larger worlds switch to on-device sampling.
    """
    K = len(client_data)
    if cfg.local_iters == 0:
        xb, yb = empty_client_batches(client_data, cfg)
        return (jnp.zeros((cfg.rounds,) + xb.shape, xb.dtype),
                jnp.zeros((cfg.rounds,) + yb.shape, yb.dtype))
    iters = [BatchIterator(ds, cfg.batch_size, seed=cfg.seed + 17 * k)
             for k, ds in enumerate(client_data)]
    xs, ys = [], []
    for _ in range(cfg.rounds):
        xt, yt = [], []
        for _ in range(cfg.local_iters):
            xb, yb = client_batches(iters)
            xt.append(xb)
            yt.append(yb)
        xs.append(jnp.stack(xt, axis=1))   # [K, L, B, ...]
        ys.append(jnp.stack(yt, axis=1))
    return jnp.stack(xs), jnp.stack(ys)    # [T, K, L, B, ...]


def resolve_data_path(client_data: Sequence[Dataset], cfg: SimConfig,
                      override: str | None = None,
                      budget_bytes: int | None = None) -> str:
    """Resolve ``cfg.data_path`` to a concrete path name.

    ``"auto"`` consults :func:`repro.data.device.choose_data_path` (padded
    store footprint vs the device memory budget); explicit names pass
    through.  Both engines (scan and legacy host loop) resolve through this
    single function so they always consume the same minibatch stream.
    """
    path = override or cfg.data_path
    if path == "auto":
        path = choose_data_path(client_data, budget_bytes)
    if path not in ("prestack", "device", "stream"):
        raise ValueError(f"unknown data_path {path!r} "
                         "(expected auto|prestack|device|stream)")
    if cfg.data_stream not in ("round", "client"):
        raise ValueError(f"unknown data_stream {cfg.data_stream!r} "
                         "(expected round|client)")
    if cfg.data_stream == "client" and path != "device":
        raise ValueError(
            "the per-client minibatch stream is defined on the device data "
            f"path only (resolved path: {path!r}); pass data_path='device'")
    return path


# ---------------------------------------------------------------------------
# the scan engine
# ---------------------------------------------------------------------------


def _client_mesh(num_clients: int):
    """1-D ``("k",)`` mesh over the largest divisor-of-K device prefix, or
    ``None`` when only one device is visible (sharding becomes a no-op)."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    d = max(i for i in range(1, min(len(devs), num_clients) + 1)
            if num_clients % i == 0)
    if d <= 1:
        return None
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:d]), ("k",))


def init_carry(params: Any, num_clients: int, cfg: SimConfig):
    """The scan carry: ``(FLState, energy)``, plus the per-client
    :class:`~repro.fl.faults.FaultState` when fault injection is on.  The
    faults-off structure is exactly the pre-robustness carry — existing
    programs are untouched."""
    state0 = init_fl_state(params, num_clients)
    energy0 = jnp.zeros((num_clients,), jnp.float32)
    carry = (state0, energy0)
    if cfg.faults is not None:
        carry = carry + (init_fault_state(num_clients),)
    ms = init_metrics(cfg.metrics, num_clients, cfg.guards)
    if ms is not None:       # metrics taps ride last in the carry
        carry = carry + (ms,)
    return carry


def _make_round_step(vtrain: Callable, loss_fn: Callable, acc_fn: Callable,
                     cfg: SimConfig, cell: CellConfig, num_clients: int,
                     policy_fn: PolicyFn, hoist: bool):
    """The per-round transition shared by every execution mode (full scan
    over pre-stacked batches, in-scan device-store sampling, streaming
    round-chunks): protocol Steps 1-5, fault pipeline, energy ledger,
    defensive aggregation, strided eval."""
    K = num_clients
    faults = cfg.faults
    guards = cfg.guards
    agg = cfg.aggregator
    tapped = metrics_active(cfg.metrics, guards)
    if cfg.eval_mode not in ("inscan", "replay"):
        raise ValueError(f"unknown eval_mode {cfg.eval_mode!r} "
                         "(expected inscan|replay)")

    def round_step(carry, t, h_t, xb, yb, pw, base_key, test_x, test_y,
                   fp=None, ap=None):
        state, energy = carry[0], carry[1]
        if faults is not None:
            fstate = carry[2]
        if tapped:
            mstate = carry[-1]
        # --- Steps 2-4: policy, Bernoulli draws, Δ_k, energy (eq. 5) -------
        probs, w = pw if hoist else policy_fn(t, h_t, state)
        mask, forced, w, e_round = apply_round_decision(
            probs, w, t, h_t, state, base_key, cfg, cell, K)
        # decision energy before the fault pipeline — the taps' retry-
        # overhead lane is Σ relu(paid − decided)
        e_base = e_round
        # --- fault pipeline: availability → crash → lossy uplink -----------
        # (salted fold_in streams — the decision draw above is untouched)
        if faults is not None:
            out, fstate = apply_faults(t, base_key, mask, e_round, fstate,
                                       fp, faults)
            delivered, corrupt, e_round = out.delivered, out.corrupt, \
                out.e_round
        else:
            delivered = mask
            corrupt = jnp.zeros((K,), bool)
        energy = energy + e_round
        # --- Step 1 (local training) + Steps 4-5 ---------------------------
        client = vtrain(state.client_params, xb, yb)
        if cfg.local_mode == "participants":
            # only clients whose update lands move; everyone else keeps
            # client == anchor (their pseudo-gradient stays exactly zero —
            # a crashed/lost upload's training is discarded with it)
            def keep(new, old):
                m = delivered.reshape(
                    (-1,) + (1,) * (new.ndim - 1)).astype(bool)
                return jnp.where(m, new, old)

            client = jax.tree_util.tree_map(keep, client,
                                            state.client_params)
        elif cfg.local_mode != "continuous":
            raise ValueError(f"unknown local_mode {cfg.local_mode!r} "
                             "(expected continuous|participants)")
        state = state._replace(client_params=client)
        deltas = pseudo_gradients(state)
        if faults is not None:
            deltas = corrupt_deltas(deltas, corrupt, fp, faults)
        if agg is not None:
            # pluggable scheme path (guards fold in): weights come from the
            # staleness ledger and the policy's *nominal* probs (pre-boost —
            # the csmaafl importance weight debiases the policy, not the
            # aging heuristic layered on top of it)
            staleness = state.round - state.last_tx
            new_global = scheme_aggregate(
                state.global_params, deltas, delivered, K, staleness, probs,
                agg.params() if ap is None else ap, guards=guards)
        elif guards is not None and guards.active:
            staleness = state.round - state.last_tx
            new_global = guarded_aggregate(state.global_params, deltas,
                                           delivered, K, staleness, guards)
        else:
            new_global = masked_aggregate(state.global_params, deltas,
                                          delivered, K)
        if tapped:
            ap_eff = ((agg.params() if ap is None else ap)
                      if agg is not None else None)
            mstate = metrics_round_update(
                mstate, cfg.metrics, mask=mask, forced=forced, e_base=e_base,
                e_round=e_round, staleness=state.round - state.last_tx,
                delivered=delivered, deltas=deltas, probs=probs,
                num_clients=K, guards=guards, agg_params=ap_eff)
        state = broadcast_to_participants(state, new_global, delivered)

        # --- strided eval (stays on device; read back once at the end).
        # "replay" skips the cond entirely — the resumable driver evaluates
        # its strided param checkpoints post-hoc instead (both lax.cond
        # branches execute under vmap, so matrix sweeps want this off) -----
        if cfg.eval_mode == "replay":
            acc = jnp.zeros((), jnp.float32)
            loss = jnp.zeros((), jnp.float32)
            do_eval = jnp.zeros((), bool)
        else:
            def eval_now(p):
                return (jnp.asarray(acc_fn(p, test_x, test_y), jnp.float32),
                        jnp.asarray(loss_fn(p, test_x, test_y), jnp.float32))

            def skip_eval(p):
                del p
                return jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)

            do_eval = jnp.logical_or(t % cfg.eval_every == 0,
                                     t == cfg.rounds - 1)
            acc, loss = jax.lax.cond(do_eval, eval_now, skip_eval,
                                     state.global_params)
        carry = (state, energy)
        if faults is not None:
            carry = carry + (fstate,)
        if tapped:
            carry = carry + (mstate,)
        return carry, RoundTrace(mask, e_round, acc, loss, do_eval,
                                 delivered, corrupt)

    return round_step


def build_scan_sim(loss_fn: Callable, acc_fn: Callable, opt: Optimizer,
                   cfg: SimConfig, cell: CellConfig, num_clients: int,
                   policy_fn: PolicyFn, shard_clients: bool | None = None,
                   data_mode: str = "prestack"):
    """Build the pure simulation function (one ``lax.scan`` over all rounds).

    ``data_mode`` selects how the scan obtains its minibatches:

    * ``"prestack"`` — ``simulate(params, xb_all, yb_all, h_rounds, base_key,
      test_x, test_y)``: batches arrive as ``[T, K, L, B, ...]`` scan inputs
      (the legacy layout; peak memory grows linearly in T).
    * ``"device"`` — ``simulate(params, store, data_key, h_rounds, base_key,
      test_x, test_y)``: each round gathers its batch from a
      :class:`~repro.data.device.DeviceDataStore` *inside* the scan body via
      the ``fold_in(data_key, t)`` stream — no T-proportional buffer exists
      anywhere in the program.

    Either way the returned function yields ``(FLState, energy [K],
    RoundTrace[T])`` and is traceable end-to-end: jit it for a single run,
    vmap it over ``(base_key, h_rounds)`` (and a traced ρ via the policy
    closure) for scenario fan-out.  ``h_rounds`` is round-major ``[T, K]``.

    Policies tagged ``state_free`` (all five paper schemes) are hoisted out
    of the sequential scan: every round's ``(probs, w)`` is computed in one
    ``vmap`` over ``t`` before the scan — T serial (P1') solves become one
    batched solve, still inside the same device program.  Untagged policies
    (anything reading the carried ``FLState``) run inside the scan body.

    ``shard_clients`` (default auto): when multiple devices are visible and
    divide K, the client axis — the data-parallel mesh axis of the FL state —
    is sharded via ``shard_map`` for the local-training leg, and GSPMD
    propagates the sharding through the aggregation/broadcast tree ops (in
    device mode the store's client axis is placed on the same mesh by
    ``make_runner``).  Auto-disabled on a single device; pass ``False`` to
    force off (the vmap matrix runners do, sharding does not compose with
    their lane axis).
    """
    K = num_clients
    vtrain = make_local_train(loss_fn, opt)
    hoist = getattr(policy_fn, "state_free", False)
    mesh = _client_mesh(K) if shard_clients in (None, True) else None
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        vtrain = shard_map(vtrain, mesh,
                           in_specs=(P("k"), P("k"), P("k")),
                           out_specs=P("k"))
    round_step = _make_round_step(vtrain, loss_fn, acc_fn, cfg, cell, K,
                                  policy_fn, hoist)

    def hoisted_policy(h_rounds):
        ts = jnp.arange(cfg.rounds, dtype=jnp.int32)
        return jax.vmap(lambda t, h: policy_fn(t, h, None))(ts, h_rounds)

    def _resolve_pw(h_rounds, pw_all):
        if hoist:
            return hoisted_policy(h_rounds) if pw_all is None else pw_all
        # dummy per-round operands; the policy runs in the scan body
        return (jnp.zeros((cfg.rounds, 0)),) * 2

    def _resolve_fp(fault_params):
        if cfg.faults is None:
            return None
        return cfg.faults.params() if fault_params is None else fault_params

    def _resolve_ap(agg_params):
        if cfg.aggregator is None:
            return None
        return cfg.aggregator.params() if agg_params is None else agg_params

    tapped = metrics_active(cfg.metrics, cfg.guards)

    def _scan(params, step, xs):
        carry0 = init_carry(params, K, cfg)
        final, traces = jax.lax.scan(step, carry0, xs)
        state, energy = final[0], final[1]
        if tapped:       # 4-tuple only when taps materialize (static on cfg)
            return state, energy, traces, final[-1]
        return state, energy, traces

    if data_mode == "prestack":
        def simulate(params, xb_all, yb_all, h_rounds, base_key, test_x,
                     test_y, pw_all=None, fault_params=None, agg_params=None):
            ts_all = jnp.arange(cfg.rounds, dtype=jnp.int32)
            pw_all = _resolve_pw(h_rounds, pw_all)
            fp = _resolve_fp(fault_params)
            ap = _resolve_ap(agg_params)

            def step(carry, xs):
                t, h_t, xb, yb, pw = xs
                return round_step(carry, t, h_t, xb, yb, pw, base_key,
                                  test_x, test_y, fp=fp, ap=ap)

            return _scan(params, step, (ts_all, h_rounds, xb_all, yb_all,
                                        pw_all))
    elif data_mode == "device":
        def simulate(params, store, data_key, h_rounds, base_key, test_x,
                     test_y, pw_all=None, fault_params=None, agg_params=None):
            ts_all = jnp.arange(cfg.rounds, dtype=jnp.int32)
            pw_all = _resolve_pw(h_rounds, pw_all)
            fp = _resolve_fp(fault_params)
            ap = _resolve_ap(agg_params)

            sample = (sample_round_client_stream
                      if cfg.data_stream == "client" else sample_round)

            def step(carry, xs):
                t, h_t, pw = xs
                xb, yb = sample(store, data_key, t, cfg.local_iters,
                                cfg.batch_size)
                return round_step(carry, t, h_t, xb, yb, pw, base_key,
                                  test_x, test_y, fp=fp, ap=ap)

            return _scan(params, step, (ts_all, h_rounds, pw_all))
    else:
        raise ValueError(f"unknown data_mode {data_mode!r}")

    # under client-axis sharding the tiny [T, K] policy solve pays SPMD
    # partitioning overhead inside the main program — callers (make_runner)
    # run it as its own replicated jit and pass pw_all in
    simulate.split_policy = hoist and mesh is not None
    simulate.hoisted_policy = hoisted_policy
    simulate.mesh = mesh
    return simulate


def build_chunk_sim(loss_fn: Callable, acc_fn: Callable, opt: Optimizer,
                    cfg: SimConfig, cell: CellConfig, num_clients: int,
                    policy_fn: PolicyFn, data_mode: str = "prestack"):
    """Streaming/resumable building block: the identical round transition
    scanned over one round-*chunk* with an explicit carry (see
    :func:`init_carry` — ``(FLState, energy[, FaultState])``).

    ``data_mode="prestack"``: ``chunk(carry, ts, h, xb, yb, pw, base_key,
    test_x, test_y, fault_params=None)`` consumes absolute round ids ``ts``
    (so ``fold_in(·, t)`` streams and the eval-stride/final-round conditions
    match the single-scan engines bit-wise) and chunk-major batch arrays
    ``[C, K, L, B, ...]``; the host loop threads the carry across chunks
    (see ``make_runner``'s stream path).

    ``data_mode="device"``: ``chunk(carry, ts, h, pw, store, data_key,
    base_key, test_x, test_y, fault_params=None)`` gathers each round's
    batch from the resident store inside the chunk body — what the
    resumable driver (:mod:`repro.fl.resume`) runs segment by segment.
    """
    vtrain = make_local_train(loss_fn, opt)
    hoist = getattr(policy_fn, "state_free", False)
    round_step = _make_round_step(vtrain, loss_fn, acc_fn, cfg, cell,
                                  num_clients, policy_fn, hoist)

    def _fp(fault_params):
        if cfg.faults is None:
            return None
        return cfg.faults.params() if fault_params is None else fault_params

    def _ap(agg_params):
        if cfg.aggregator is None:
            return None
        return cfg.aggregator.params() if agg_params is None else agg_params

    if data_mode == "prestack":
        def chunk(carry, ts, h, xb, yb, pw, base_key, test_x, test_y,
                  fault_params=None, agg_params=None):
            fp = _fp(fault_params)
            ap = _ap(agg_params)

            def step(c, xs):
                t, h_t, xbt, ybt, pwt = xs
                return round_step(c, t, h_t, xbt, ybt, pwt, base_key,
                                  test_x, test_y, fp=fp, ap=ap)

            return jax.lax.scan(step, carry, (ts, h, xb, yb, pw))
    elif data_mode == "device":
        def chunk(carry, ts, h, pw, store, data_key, base_key, test_x,
                  test_y, fault_params=None, agg_params=None):
            fp = _fp(fault_params)
            ap = _ap(agg_params)
            sample = (sample_round_client_stream
                      if cfg.data_stream == "client" else sample_round)

            def step(c, xs):
                t, h_t, pwt = xs
                xb, yb = sample(store, data_key, t, cfg.local_iters,
                                cfg.batch_size)
                return round_step(c, t, h_t, xb, yb, pwt, base_key,
                                  test_x, test_y, fp=fp, ap=ap)

            return jax.lax.scan(step, carry, (ts, h, pw))
    else:
        raise ValueError(f"unknown data_mode {data_mode!r}")

    chunk.hoist = hoist
    return chunk


def _to_result(state, energy, traces, cfg: SimConfig,
               mstate=None) -> SimResult:
    """Single end-of-run host readback → legacy ``SimResult``."""
    did = np.asarray(traces.did_eval)
    idx = np.where(did)[0]
    e_round = np.asarray(traces.e_round)               # [T, K]
    faulty = cfg.faults is not None
    return SimResult(
        test_acc=np.asarray(traces.acc)[idx],
        test_loss=np.asarray(traces.loss)[idx],
        eval_rounds=idx,
        energy_per_client=np.asarray(energy),
        energy_timeline=np.cumsum(e_round.sum(axis=1)),
        participation=np.asarray(traces.mask),
        state=state,
        delivered=np.asarray(traces.delivered) if faulty else None,
        corrupted=np.asarray(traces.corrupt) if faulty else None,
        metrics=(jax.tree_util.tree_map(np.asarray, mstate)
                 if mstate is not None else None),
    )


def _make_stream_runner(loss_fn: Callable, acc_fn: Callable,
                        client_data: Sequence[Dataset], test_x, test_y,
                        policy_fn: PolicyFn, cell: CellConfig, cfg: SimConfig,
                        opt: Optimizer) -> Callable:
    """Host-streaming execution: the horizon is split into
    ``cfg.stream_chunk``-round segments; chunk ``i+1``'s batches are gathered
    host-side (same ``fold_in`` index stream as the device store — batches
    are bit-identical) and ``device_put`` while chunk ``i`` computes, so
    device-resident data never exceeds two chunks regardless of T or the
    dataset size."""
    K = len(client_data)
    T = cfg.rounds
    sampler = StreamingSampler(client_data, data_stream_key(cfg.seed),
                               cfg.local_iters, cfg.batch_size)
    raw = build_chunk_sim(loss_fn, acc_fn, opt, cfg, cell, K, policy_fn)
    hoist = raw.hoist
    tapped = metrics_active(cfg.metrics, cfg.guards)
    chunk_fn = jax.jit(raw)
    ts_full = jnp.arange(T, dtype=jnp.int32)
    pol = (jax.jit(jax.vmap(lambda t, h: policy_fn(t, h, None)))
           if hoist else None)
    C = max(1, int(cfg.stream_chunk))
    bounds = [(t0, min(t0 + C, T)) for t0 in range(0, T, C)]

    def runner(params, h_all, seed: int | None = None) -> SimResult:
        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        h_rounds = jnp.swapaxes(h_all, 0, 1)
        pw_full = (pol(ts_full, h_rounds) if hoist
                   else (jnp.zeros((T, 0)),) * 2)
        carry = init_carry(params, K, cfg)
        buf = sampler.chunk(*bounds[0])
        traces = []
        for i, (t0, t1) in enumerate(bounds):
            pw_c = jax.tree_util.tree_map(lambda p: p[t0:t1], pw_full)
            carry, tr = chunk_fn(carry, ts_full[t0:t1], h_rounds[t0:t1],
                                 buf[0], buf[1], pw_c, key, test_x, test_y)
            if i + 1 < len(bounds):   # prefetch overlaps the async chunk
                buf = sampler.chunk(*bounds[i + 1])
            traces.append(tr)
        state, energy = carry[0], carry[1]
        traces = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *traces)
        return _to_result(state, energy, traces, cfg,
                          mstate=carry[-1] if tapped else None)

    return runner


def make_runner(loss_fn: Callable, acc_fn: Callable,
                client_data: Sequence[Dataset], test_ds: Dataset, policy,
                cell: CellConfig, cfg: SimConfig,
                opt: Optimizer | None = None,
                shard_clients: bool | None = None,
                data_path: str | None = None,
                data_budget_bytes: int | None = None) -> Callable:
    """Pre-build the compiled scan runner for repeated invocations.

    Returns ``runner(params, h_all, seed=None) -> SimResult``; the jitted
    scan program and the data source (device store, streamed blocks, or the
    legacy pre-stack) are built once and reused, so successive calls (new
    channel draws, new PRNG seeds, warm benchmarking) pay zero
    re-trace/re-pack cost.

    ``data_path`` overrides ``cfg.data_path`` (``"auto"`` resolves by
    footprint; see :func:`resolve_data_path`).  On the device path the
    store's client axis is placed on the same mesh as the FL state whenever
    client-axis sharding is active.
    """
    K = len(client_data)
    policy_fn = as_policy_fn(policy)
    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    path = resolve_data_path(client_data, cfg, data_path, data_budget_bytes)

    from .sparse import make_sparse_runner, resolve_participation
    if resolve_participation(cfg, policy_fn, path, K) == "sparse":
        # opt passed un-defaulted: the sparse runner tokens the default
        # optimizer by (kind, lr) so its participant-program cache hits
        # across runners (a fresh sgd() closure per call would miss on id)
        return make_sparse_runner(loss_fn, acc_fn, client_data, test_ds,
                                  policy_fn, cell, cfg, opt=opt)
    opt = opt or sgd(cfg.lr)
    emit_run_manifest("make_runner", cfg,
                      extra={"path": path, "num_clients": K})

    if path == "stream":
        return _make_stream_runner(loss_fn, acc_fn, client_data, test_x,
                                   test_y, policy_fn, cell, cfg, opt)

    sim = build_scan_sim(loss_fn, acc_fn, opt, cfg, cell, K, policy_fn,
                         shard_clients=shard_clients, data_mode=path)
    simulate = jax.jit(sim)
    policy_pre = jax.jit(sim.hoisted_policy) if sim.split_policy else None
    tapped = metrics_active(cfg.metrics, cfg.guards)

    if path == "device":
        store = from_client_datasets(client_data)
        if sim.mesh is not None:
            from ..launch.sharding import client_axis_shardings
            store = jax.device_put(
                store, client_axis_shardings(store, sim.mesh, "k"))
        data_key = data_stream_key(cfg.seed)

        def runner(params, h_all, seed: int | None = None) -> SimResult:
            key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
            h_rounds = jnp.swapaxes(h_all, 0, 1)
            pw = policy_pre(h_rounds) if policy_pre is not None else None
            with get_telemetry().span("engine.execute"):
                out = simulate(params, store, data_key, h_rounds, key,
                               test_x, test_y, pw_all=pw)
            return _to_result(out[0], out[1], out[2], cfg,
                              mstate=out[3] if tapped else None)
    else:
        xb_all, yb_all = stack_round_batches(client_data, cfg)

        def runner(params, h_all, seed: int | None = None) -> SimResult:
            key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
            h_rounds = jnp.swapaxes(h_all, 0, 1)
            pw = policy_pre(h_rounds) if policy_pre is not None else None
            with get_telemetry().span("engine.execute"):
                out = simulate(params, xb_all, yb_all, h_rounds, key,
                               test_x, test_y, pw_all=pw)
            return _to_result(out[0], out[1], out[2], cfg,
                              mstate=out[3] if tapped else None)

    return runner


def run_simulation_scan(init_params: Any,
                        loss_fn: Callable,
                        acc_fn: Callable,
                        client_data: Sequence[Dataset],
                        test_ds: Dataset,
                        policy,
                        h_all: jax.Array,           # [K, rounds]
                        cell: CellConfig,
                        cfg: SimConfig,
                        opt: Optimizer | None = None) -> SimResult:
    """Scan-engine drop-in for the legacy ``run_simulation`` signature."""
    return make_runner(loss_fn, acc_fn, client_data, test_ds, policy, cell,
                       cfg, opt)(init_params, h_all)


# ---------------------------------------------------------------------------
# scenario fan-out: vmap the whole simulation over lanes and ρ
# ---------------------------------------------------------------------------


class MatrixResult(NamedTuple):
    """Stacked traces; leading axes are the vmapped ones ([R, S, ...] for
    :func:`run_scenario_matrix`, [S, ...] for :func:`run_seed_matrix`)."""

    acc: np.ndarray          # [..., n_evals]
    loss: np.ndarray         # [..., n_evals]
    eval_rounds: np.ndarray  # [n_evals]
    energy: np.ndarray       # [..., K] cumulative per-client Joules
    e_round: np.ndarray      # [..., T, K]
    participation: np.ndarray  # [..., T, K]
    # per-lane MetricsState (leading axes = the vmapped ones) when
    # cfg.metrics enables taps; None otherwise.
    metrics: Any = None


def _matrix_result(energy, traces, mstate=None) -> MatrixResult:
    did = np.asarray(traces.did_eval)
    # did_eval depends only on t — identical across lanes; collapse to [T].
    did_t = did.reshape(-1, did.shape[-1])[0]
    idx = np.where(did_t)[0]
    return MatrixResult(
        acc=np.asarray(traces.acc)[..., idx],
        loss=np.asarray(traces.loss)[..., idx],
        eval_rounds=idx,
        energy=np.asarray(energy),
        e_round=np.asarray(traces.e_round),
        participation=np.asarray(traces.mask),
        metrics=(jax.tree_util.tree_map(np.asarray, mstate)
                 if mstate is not None else None),
    )


def run_seed_matrix(init_params, loss_fn, acc_fn, client_data, test_ds,
                    policy, h_stack: jax.Array, cell: CellConfig,
                    cfg: SimConfig, seeds: Sequence[int],
                    opt: Optimizer | None = None) -> MatrixResult:
    """vmap the scan engine over scenario lanes for one policy.

    ``h_stack: [S, K, T]`` stacked channel realizations (one per lane —
    seeds, placements, fading draws); ``seeds`` gives each lane its own
    participation PRNG stream.  One compiled device program runs every lane.

    Data rides along un-vmapped: the device store (or the legacy pre-stack
    when ``cfg.data_path`` forces it) is shared by all lanes, and the
    minibatch stream is keyed by ``cfg.seed`` only — lanes differ in
    channel/participation randomness, not in data.  A resolved ``"stream"``
    path falls back to the device store here (lane fan-out multiplies every
    buffer anyway, so host streaming buys nothing under vmap).
    """
    K = h_stack.shape[1]
    opt = opt or sgd(cfg.lr)
    policy_fn = as_policy_fn(policy)
    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    h_rounds = jnp.swapaxes(h_stack, 1, 2)             # [S, T, K]
    path = resolve_data_path(client_data, cfg)
    if path == "prestack":
        xb_all, yb_all = stack_round_batches(client_data, cfg)
        simulate = build_scan_sim(loss_fn, acc_fn, opt, cfg, cell, K,
                                  policy_fn, shard_clients=False)
        fan = jax.jit(jax.vmap(
            lambda key, h: simulate(init_params, xb_all, yb_all, h, key,
                                    test_x, test_y)))
    else:
        store = from_client_datasets(client_data)
        data_key = data_stream_key(cfg.seed)
        simulate = build_scan_sim(loss_fn, acc_fn, opt, cfg, cell, K,
                                  policy_fn, shard_clients=False,
                                  data_mode="device")
        fan = jax.jit(jax.vmap(
            lambda key, h: simulate(init_params, store, data_key, h, key,
                                    test_x, test_y)))
    emit_run_manifest("run_seed_matrix", cfg,
                      extra={"lanes": len(seeds), "num_clients": K})
    with get_telemetry().span("seed_matrix.execute"):
        out = fan(keys, h_rounds)
    tapped = metrics_active(cfg.metrics, cfg.guards)
    return _matrix_result(out[1], out[2],
                          mstate=out[3] if tapped else None)


def run_scenario_matrix(init_params, loss_fn, acc_fn, client_data, test_ds,
                        spec, h_stack: jax.Array, rhos: Sequence[float],
                        cfg: SimConfig, seeds: Sequence[int],
                        opt: Optimizer | None = None) -> MatrixResult:
    """ρ × lane fan-out of the paper's online scheme in one device program.

    The tradeoff coefficient ρ of (P1') is traced through ``solve_online``
    (see :func:`repro.core.online.solve_online`), so the full Fig. 6-9-style
    sweep — ρ on one vmap axis, channel/seed lanes on the other — compiles
    once and runs entirely on device.  Returns ``MatrixResult`` with leading
    axes ``[R, S]``.  Sweep K by calling once per client count (shapes
    change, so K cannot share a vmap axis).
    """
    K = h_stack.shape[1]
    cell = spec.cell
    opt = opt or sgd(cfg.lr)
    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    h_rounds = jnp.swapaxes(h_stack, 1, 2)             # [S, T, K]
    # stream resolves to the device store here, as in run_seed_matrix
    path = resolve_data_path(client_data, cfg)
    if path == "prestack":
        data = stack_round_batches(client_data, cfg)
    else:
        data = (from_client_datasets(client_data), data_stream_key(cfg.seed))

    def one(rho, key, h):
        simulate = build_scan_sim(loss_fn, acc_fn, opt, cfg, cell, K,
                                  online_policy(spec, rho=rho),
                                  shard_clients=False,
                                  data_mode=("prestack" if path == "prestack"
                                             else "device"))
        return simulate(init_params, data[0], data[1], h, key, test_x, test_y)

    lanes = jax.vmap(one, in_axes=(None, 0, 0))        # scenario lanes
    fan = jax.jit(jax.vmap(lanes, in_axes=(0, None, None)))  # ρ axis
    emit_run_manifest("run_scenario_matrix", cfg,
                      extra={"rhos": len(rhos), "lanes": len(seeds),
                             "num_clients": K})
    with get_telemetry().span("scenario_matrix.execute"):
        out = fan(jnp.asarray(rhos, jnp.float32), keys, h_rounds)
    tapped = metrics_active(cfg.metrics, cfg.guards)
    return _matrix_result(out[1], out[2],
                          mstate=out[3] if tapped else None)
