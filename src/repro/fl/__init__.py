"""FL runtime: scan-based async simulation engine + mega-scale distributed step."""
from .engine import (MatrixResult, RoundTrace, SimConfig, SimResult,
                     build_chunk_sim, build_scan_sim, grant_forced_bandwidth,
                     init_carry, make_runner, resolve_data_path,
                     run_scenario_matrix, run_seed_matrix,
                     run_simulation_scan, stack_round_batches)
from .faults import (FaultConfig, FaultMatrixResult, FaultOutcome,
                     FaultParams, FaultState, GuardConfig, apply_faults,
                     corrupt_deltas, fault_key, init_fault_state,
                     run_fault_matrix, scale_params)
from .resume import completed_segments, run_resumable, segment_bounds
from .schemes import (SchemeMatrixResult, SchemeSpec, default_scheme_panel,
                      run_scheme_matrix)
from .simulator import run_simulation, run_simulation_legacy
from .sparse import (ParticipationTrace, build_participation_program,
                     build_sparse_train_program, make_sparse_runner,
                     resolve_participation, train_trace_count)
from .state import (AggParams, AggregatorConfig, FLState,
                    broadcast_to_participants, finite_rows, guard_weights,
                    guarded_aggregate, guarded_subset_aggregate,
                    init_fl_state, masked_aggregate, pseudo_gradients,
                    scheme_aggregate, scheme_subset_aggregate, scheme_weights,
                    staleness_scale, subset_aggregate, update_norms,
                    weighted_aggregate)

__all__ = ["SimConfig", "SimResult", "run_simulation",
           "run_simulation_legacy", "run_simulation_scan", "build_scan_sim",
           "build_chunk_sim", "make_runner", "resolve_data_path",
           "run_seed_matrix", "run_scenario_matrix", "stack_round_batches",
           "grant_forced_bandwidth", "MatrixResult", "RoundTrace", "FLState",
           "init_fl_state", "init_carry", "masked_aggregate",
           "pseudo_gradients", "subset_aggregate",
           "broadcast_to_participants", "make_sparse_runner",
           "resolve_participation", "build_participation_program",
           "build_sparse_train_program", "ParticipationTrace",
           "train_trace_count",
           # robustness layer (docs/robustness.md)
           "FaultConfig", "FaultParams", "FaultState", "FaultOutcome",
           "GuardConfig", "FaultMatrixResult", "apply_faults",
           "corrupt_deltas", "fault_key", "init_fault_state", "scale_params",
           "run_fault_matrix", "finite_rows", "update_norms",
           "guard_weights", "guarded_aggregate", "guarded_subset_aggregate",
           "run_resumable", "segment_bounds", "completed_segments",
           # scheme matrix (docs/schemes.md)
           "AggParams", "AggregatorConfig", "SchemeMatrixResult",
           "SchemeSpec", "default_scheme_panel", "run_scheme_matrix",
           "scheme_aggregate", "scheme_subset_aggregate", "scheme_weights",
           "staleness_scale", "weighted_aggregate"]
