"""FL runtime: paper-faithful async simulator + mega-scale distributed step."""
from .simulator import SimConfig, SimResult, run_simulation
from .state import (FLState, init_fl_state, masked_aggregate,
                    pseudo_gradients, broadcast_to_participants)

__all__ = ["SimConfig", "SimResult", "run_simulation", "FLState",
           "init_fl_state", "masked_aggregate", "pseudo_gradients",
           "broadcast_to_participants"]
