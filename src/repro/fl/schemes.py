"""Head-to-head async-FL scheme matrix: one device program per path.

The paper's claim is comparative — probabilistic client selection vs the
traditional async-FL designs — so the engine needs every competing scheme
running under *identical* channel realizations, PRNG streams, and energy
accounting.  A scheme here is a pair:

* a **selection policy** (:mod:`repro.core.selection`): who transmits —
  the paper's online solve, random/greedy/age heuristics, CSMA-style
  channel-share contention (arXiv:2306.01207), or Hu–Chen–Larsson
  age-aware scheduling (arXiv:2212.07356, a *ledger* policy);
* an **aggregator** (:class:`repro.fl.state.AggregatorConfig`): how the
  delivered deltas merge — the paper's 1/K average, FedAsync-style
  ``s(Δτ)`` staleness mixing (constant/hinge/poly), CSMAAFL importance
  weighting, or age-aware amplification.

``run_scheme_matrix`` fans schemes × seeds × non-IID severities out as
vmap axes of **one compiled program per execution path**.  Schemes become
a traced axis through two devices:

* the policy panel is blended by a traced one-hot row
  (:func:`repro.core.selection.policy_blend` — 0/1 float blending is
  IEEE-exact, so each lane realizes its policy's probs bit-for-bit);
* the aggregator panel is a stacked :class:`~repro.fl.state.AggParams`
  whose one-hot selectors ride the same vmap axis (the branch-free weight
  program in :func:`~repro.fl.state.scheme_weights`).

Severities vmap over stacked :class:`~repro.data.device.DeviceDataStore`
leaves (same shapes — build them with a shared ``pad_to``); seeds pair a
participation PRNG stream with a channel realization lane, exactly like
:func:`repro.fl.engine.run_seed_matrix`.

Both paths share phase-level machinery with their single-run engines, so
the golden-trace layer (tests/golden/) pins their trajectories.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig
from ..core.selection import (as_policy_fn, participant_bucket,
                              policy_blend, policy_ledger_ok)
from ..data.device import (DeviceDataStore, data_stream_key,
                           from_client_datasets, gather_participant_rounds)
from ..obs.taps import merge_metrics, metrics_active
from ..obs.telemetry import emit_run_manifest, get_telemetry
from ..optim import Optimizer, sgd
from .state import AggParams, AggregatorConfig

__all__ = ["SchemeSpec", "SchemeMatrixResult", "default_scheme_panel",
           "run_scheme_matrix", "stack_stores"]


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """One lane of the comparison: a named (policy, aggregator) pair."""

    name: str
    policy: Any                       # PolicyFn or legacy Policy object
    aggregator: AggregatorConfig

    def policy_fn(self):
        return as_policy_fn(self.policy)


def default_scheme_panel(spec, num_clients: int, rhos: Sequence[float] = (),
                         p_bar: float = 0.25) -> list[SchemeSpec]:
    """The Fig. 6-7 comparison panel: the paper's scheme against the three
    baseline families from the related work.

    ``spec`` is the :class:`~repro.core.problem.ProblemSpec` the paper's
    online solve needs; ``rhos`` adds one paper lane per tradeoff
    coefficient (empty keeps a single ``rho=None`` lane).  ``p_bar`` sets
    the baselines' expected participation fraction so energy budgets are
    comparable across lanes.
    """
    from ..core.selection import (age_aware_policy, csma_policy,
                                  online_policy, random_policy)

    K = num_clients
    k = max(1, int(round(p_bar * K)))
    panel = []
    if rhos:
        for rho in rhos:
            panel.append(SchemeSpec(
                f"paper-rho{rho:g}", online_policy(spec, rho=float(rho)),
                AggregatorConfig(kind="paper")))
    else:
        panel.append(SchemeSpec("paper", online_policy(spec),
                                AggregatorConfig(kind="paper")))
    panel += [
        SchemeSpec("fedasync-poly", random_policy(p_bar, K),
                   AggregatorConfig(kind="fedasync", staleness_fn="poly")),
        SchemeSpec("fedasync-hinge", random_policy(p_bar, K),
                   AggregatorConfig(kind="fedasync", staleness_fn="hinge")),
        SchemeSpec("csmaafl", csma_policy(k, K),
                   AggregatorConfig(kind="csmaafl")),
        SchemeSpec("age-aware", age_aware_policy(k, K),
                   AggregatorConfig(kind="age")),
    ]
    return panel


class SchemeMatrixResult(NamedTuple):
    """Stacked traces with leading axes ``[V, L, S]`` = severities ×
    schemes × seed lanes."""

    schemes: tuple                 # L lane names
    acc: np.ndarray                # [V, L, S, n_evals]
    loss: np.ndarray               # [V, L, S, n_evals]
    eval_rounds: np.ndarray        # [n_evals]
    energy: np.ndarray             # [V, L, S, K] cumulative Joules
    energy_timeline: np.ndarray    # [V, L, S, T] cumulative total Joules
    participation: np.ndarray      # [V, L, S, T, K]
    # per-lane MetricsState ([V, L, S]-leading leaves) when cfg.metrics
    # enables taps; None otherwise.
    metrics: Any = None


def stack_stores(stores: Sequence[DeviceDataStore]) -> DeviceDataStore:
    """Stack same-shaped severity stores onto a leading vmap axis.

    Build the members with a shared ``pad_to`` cap
    (:func:`~repro.data.device.from_client_datasets`) — severity changes
    the per-client *distribution*, not the padded shapes.
    """
    first = jax.tree_util.tree_map(lambda l: (l.shape, l.dtype), stores[0])
    for s in stores[1:]:
        other = jax.tree_util.tree_map(lambda l: (l.shape, l.dtype), s)
        if other != first:
            raise ValueError(
                "severity stores must share shapes/dtypes to ride one vmap "
                "axis — build them with a common pad_to cap")
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *stores)


def _as_store(data) -> DeviceDataStore:
    return (data if isinstance(data, DeviceDataStore)
            else from_client_datasets(data))


def _stack_agg_params(schemes: Sequence[SchemeSpec]) -> AggParams:
    return jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *[s.aggregator.params() for s in schemes])


def _collapse_evals(dids: np.ndarray) -> np.ndarray:
    # did_eval depends only on t — identical across every lane
    did_t = dids.reshape(-1, dids.shape[-1])[0]
    return np.where(did_t)[0]


def run_scheme_matrix(init_params, loss_fn: Callable, acc_fn: Callable,
                      stores, test_ds, schemes: Sequence[SchemeSpec],
                      h_stack: jax.Array, cell: CellConfig, cfg,
                      seeds: Sequence[int], opt: Optimizer | None = None,
                      participation: str = "dense") -> SchemeMatrixResult:
    """Run every scheme × seed lane × severity in one device program.

    ``stores``: one client dataset list / :class:`DeviceDataStore`, or a
    sequence of them (the non-IID severity axis; shapes must match — see
    :func:`stack_stores`).  ``h_stack: [S, K, T]`` channel realizations
    pair with ``seeds`` as in :func:`~repro.fl.engine.run_seed_matrix`.

    ``participation`` picks the execution path — ``"dense"`` (the
    [K]-shaped scan engine) or ``"sparse"`` (the participant-centric
    two-phase path; requires the sparse preconditions on ``cfg`` and
    state-free/ledger policies).  Both fan out with vmap axes
    ``[V severities, L schemes, S seeds]`` and compile exactly once.

    ``cfg.aggregator`` is ignored per-lane: each scheme's
    :class:`AggregatorConfig` rides the scheme axis as traced
    :class:`AggParams`.  ``cfg.faults`` / ``cfg.guards`` thread through
    unchanged (the fault/guard carry is shared machinery with the
    single-run engines).
    """
    from .engine import build_scan_sim
    from .sparse import (build_participation_program,
                         build_sparse_train_program)

    if not schemes:
        raise ValueError("run_scheme_matrix needs at least one SchemeSpec")
    if participation not in ("dense", "sparse"):
        raise ValueError(f"unknown participation {participation!r} "
                         "(expected dense|sparse)")
    K = int(h_stack.shape[1])
    T = int(h_stack.shape[2])
    L = len(schemes)
    opt = opt or sgd(cfg.lr)
    fns = [s.policy_fn() for s in schemes]
    # the compiled program always takes the scheme branch; the per-lane
    # traced AggParams decide which weights each lane realizes
    run_cfg = dataclasses.replace(cfg, rounds=T,
                                  aggregator=schemes[0].aggregator)
    if isinstance(stores, (list, tuple)):
        store_stack = stack_stores([_as_store(s) for s in stores])
    else:
        store_stack = jax.tree_util.tree_map(
            lambda l: l[None], _as_store(stores))
    V = int(store_stack.x.shape[0])
    if int(store_stack.x.shape[1]) != K:
        raise ValueError(
            f"store client axis {int(store_stack.x.shape[1])} != channel "
            f"stack K {K}")

    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    data_key = data_stream_key(cfg.seed)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    h_rounds = jnp.swapaxes(h_stack, 1, 2)              # [S, T, K]
    sel_eye = jnp.eye(L, dtype=jnp.float32)
    ap_stack = _stack_agg_params(schemes)
    tapped = metrics_active(run_cfg.metrics, run_cfg.guards)
    emit_run_manifest("run_scheme_matrix", run_cfg,
                      extra={"path": participation, "schemes": L,
                             "lanes": len(seeds), "severities": V,
                             "num_clients": K})

    if participation == "dense":
        def one(sel, ap, key, h, store):
            pol = policy_blend(fns, sel)
            sim = build_scan_sim(loss_fn, acc_fn, opt, run_cfg, cell, K,
                                 pol, shard_clients=False,
                                 data_mode="device")
            return sim(init_params, store, data_key, h, key, test_x,
                       test_y, agg_params=ap)

        seed_lanes = jax.vmap(one, in_axes=(None, None, 0, 0, None))
        scheme_lanes = jax.vmap(seed_lanes, in_axes=(0, 0, None, None, None))
        fan = jax.jit(jax.vmap(scheme_lanes,
                               in_axes=(None, None, None, None, 0)))
        with get_telemetry().span("scheme_matrix.execute"):
            out = fan(sel_eye, ap_stack, keys, h_rounds, store_stack)
        energy, traces = out[1], out[2]
        ms = out[3] if tapped else None
        e_round = np.asarray(traces.e_round)            # [V, L, S, T, K]
        ev = _collapse_evals(np.asarray(traces.did_eval))
        return SchemeMatrixResult(
            schemes=tuple(s.name for s in schemes),
            acc=np.asarray(traces.acc)[..., ev],
            loss=np.asarray(traces.loss)[..., ev],
            eval_rounds=ev,
            energy=np.asarray(energy),
            energy_timeline=np.cumsum(e_round.sum(axis=-1), axis=-1),
            participation=np.asarray(traces.mask),
            metrics=(jax.tree_util.tree_map(np.asarray, ms)
                     if ms is not None else None),
        )

    # ---- sparse path ------------------------------------------------------
    for s, fn in zip(schemes, fns):
        if not policy_ledger_ok(fn):
            raise ValueError(
                f"scheme {s.name!r}: the sparse path needs a state_free or "
                "ledger policy")
    if run_cfg.local_mode != "participants":
        raise ValueError("sparse scheme matrix requires "
                         "SimConfig(local_mode='participants')")
    if run_cfg.data_stream != "client":
        raise ValueError("sparse scheme matrix requires "
                         "SimConfig(data_stream='client')")
    bucket = run_cfg.participant_bucket
    if bucket is None:
        # shared static bucket: max expected transmitting mass over the
        # panel (ledger policies probed at zero staleness — the Poisson
        # headroom absorbs it, the overflow check below stays exact)
        ts = jnp.arange(T, dtype=jnp.int32)
        expected = 0.0
        for fn in fns:
            probs = jax.jit(jax.vmap(
                lambda t, h, f=fn: f(t, h, None)[0]))(ts, h_rounds[0])
            expected = max(expected, float(jnp.max(jnp.sum(probs, -1))))
        bucket = participant_bucket(expected, cap=K)

    ltap = metrics_active(run_cfg.metrics, None, parts="ledger")
    ttap = metrics_active(run_cfg.metrics, run_cfg.guards, parts="train")

    def one_sparse(sel, ap, key, h, store):
        pol = policy_blend(fns, sel)
        phase_a = build_participation_program(pol, run_cfg, cell, K, bucket)
        pa = phase_a(h, key)
        energy, ptr = pa[1], pa[2]
        ms_a = pa[3] if ltap else None
        xb, yb = gather_participant_rounds(store, data_key, ptr.part_idx,
                                           run_cfg.local_iters,
                                           run_cfg.batch_size)
        train = build_sparse_train_program(loss_fn, acc_fn, opt, run_cfg)
        tout = train(
            init_params, xb, yb, ptr.valid, ptr.anchor_slot, jnp.int32(K),
            test_x, test_y, ptr.delivered, ptr.corrupt, ptr.stale,
            ptr.prob, ap)
        accs, losses, dids = tout[1]
        ms_b = tout[2] if ttap else None
        # None halves are pytree structure — they vmap as absent leaves
        return energy, accs, losses, dids, ptr, merge_metrics(ms_a, ms_b)

    seed_lanes = jax.vmap(one_sparse, in_axes=(None, None, 0, 0, None))
    scheme_lanes = jax.vmap(seed_lanes, in_axes=(0, 0, None, None, None))
    fan = jax.jit(jax.vmap(scheme_lanes,
                           in_axes=(None, None, None, None, 0)))
    with get_telemetry().span("scheme_matrix.execute"):
        energy, accs, losses, dids, ptr, ms = fan(sel_eye, ap_stack, keys,
                                                  h_rounds, store_stack)
    n_tx = np.asarray(ptr.n_tx)
    if (n_tx > bucket).any():
        raise RuntimeError(
            f"scheme-matrix participant bucket overflow: a lane realized "
            f"{int(n_tx.max())} transmitters > bucket {bucket} — pass "
            "SimConfig(participant_bucket=...) with more headroom")

    # host-side densification of the [V, L, S, T, P] participant trace
    idx = np.asarray(ptr.part_idx)
    val = np.asarray(ptr.valid)
    e_p = np.asarray(ptr.e_p)
    parts = np.zeros((V, L, len(seeds), T, K), np.float32)
    e_round = np.zeros((V, L, len(seeds), T, K), np.float32)
    vi, li, si, ti, _ = np.nonzero(val)
    parts[vi, li, si, ti, idx[val]] = 1.0
    e_round[vi, li, si, ti, idx[val]] = e_p[val]
    ev = _collapse_evals(np.asarray(dids))
    return SchemeMatrixResult(
        schemes=tuple(s.name for s in schemes),
        acc=np.asarray(accs)[..., ev],
        loss=np.asarray(losses)[..., ev],
        eval_rounds=ev,
        energy=np.asarray(energy),
        energy_timeline=np.cumsum(e_round.sum(axis=-1), axis=-1),
        participation=parts,
        metrics=(jax.tree_util.tree_map(np.asarray, ms)
                 if (ltap or ttap) else None),
    )
