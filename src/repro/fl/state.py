"""FL state containers.

``FLState`` holds the server's global model plus the *stacked* per-client
states (leading axis K): each client's divergent local model ``x_k`` and its
anchor ``y_k`` — the last global model it received (paper eq. 2).  Stacking
makes the whole protocol a handful of vmapped/einsummed pytree ops, and at
mega-scale the same leading axis becomes the data-parallel mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class FLState(NamedTuple):
    global_params: Any   # pytree, the server's x_t
    client_params: Any   # pytree with leading K axis, x_{k,t}
    anchor_params: Any   # pytree with leading K axis, y_{k,t}
    round: jax.Array     # int32 scalar
    last_tx: jax.Array   # [K] int32, round of last transmission (staleness)


def replicate(params: Any, k: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), params)


def init_fl_state(params: Any, num_clients: int) -> FLState:
    stacked = replicate(params, num_clients)
    return FLState(
        global_params=params,
        client_params=stacked,
        anchor_params=stacked,
        round=jnp.zeros((), jnp.int32),
        last_tx=jnp.zeros((num_clients,), jnp.int32),
    )


def pseudo_gradients(state: FLState) -> Any:
    """Eq. (2): δ_k = x_k − y_k (stacked over clients)."""
    return jax.tree_util.tree_map(lambda c, a: c - a,
                                  state.client_params, state.anchor_params)


def masked_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                     num_clients: int, use_pallas: bool | None = None) -> Any:
    """Eq. (3): x ← x + (1/K) Σ_{k∈C_t} δ_k.

    ``use_pallas=None`` auto-selects by backend: on TPU every leaf routes
    through the fused ``kernels.fl_aggregate`` kernel (the op sits on the hot
    path of the scan engine, one HBM pass per tile); elsewhere the jnp path is
    both the oracle and the fastest option.  ``True``/``False`` force a path
    (``True`` off-TPU runs the kernel in interpret mode — for parity tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate(g.reshape(-1),
                                   d.reshape(d.shape[0], -1),
                                   mask.astype(jnp.float32), use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas)

    def agg(g, d):
        m = mask.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / num_clients

    return jax.tree_util.tree_map(agg, global_params, deltas)


def subset_aggregate(global_params: Any, deltas_p: Any, valid: jax.Array,
                     num_clients, use_pallas: bool | None = None) -> Any:
    """Participant-subset eq. (3): x ← x + (1/K) Σ_p valid_p · δ_p.

    ``deltas_p`` carries a leading *participant bucket* axis P (the gathered
    transmitting set, padded), not the population axis K; ``valid`` masks the
    padding lanes and ``num_clients`` is the population size the paper's
    1/K averaging divides by — it may be a **traced** scalar, which is what
    lets one compiled sparse round step serve every population sharing a
    bucket.  Backend dispatch matches :func:`masked_aggregate`: the fused
    Pallas kernel on TPU (subset form — see ``kernels.ops.fl_aggregate_subset``),
    the jnp oracle elsewhere.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kf = jnp.asarray(num_clients, jnp.float32)
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate_subset(
                g.reshape(-1), d.reshape(d.shape[0], -1),
                valid.astype(jnp.float32), kf, use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas_p)

    def agg(g, d):
        m = valid.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / kf

    return jax.tree_util.tree_map(agg, global_params, deltas_p)


def finite_rows(deltas: Any) -> jax.Array:
    """Per-row (client/participant) finiteness of a stacked delta pytree:
    ``[R] bool``, False when *any* element of the row, in any leaf, is
    NaN/Inf."""
    def leaf_ok(d):
        return jnp.all(jnp.isfinite(d).reshape(d.shape[0], -1), axis=1)

    oks = [leaf_ok(d) for d in jax.tree_util.tree_leaves(deltas)]
    out = oks[0]
    for o in oks[1:]:
        out = out & o
    return out


def update_norms(deltas: Any) -> jax.Array:
    """Per-row global L2 norm across every leaf of a stacked delta pytree
    (``[R] f32``).  Non-finite elements contribute 0 so the clip factor of a
    quarantined row stays well-defined (the row is rejected anyway)."""
    def leaf_sq(d):
        d = d.reshape(d.shape[0], -1).astype(jnp.float32)
        d = jnp.where(jnp.isfinite(d), d, 0.0)
        return jnp.sum(d * d, axis=1)

    sq = sum(leaf_sq(d) for d in jax.tree_util.tree_leaves(deltas))
    return jnp.sqrt(sq)


def guard_weights(deltas: Any, staleness: jax.Array, guards) -> tuple:
    """Defensive per-row weights + sanitized deltas for aggregation.

    ``guards`` is a :class:`repro.fl.faults.GuardConfig`.  Returns
    ``(weights [R] f32, deltas')`` where the effective aggregation mask is
    ``mask · weights``:

    * quarantine: non-finite rows get weight 0 **and** are zeroed in
      ``deltas'`` (``0 · NaN = NaN`` — masking alone cannot reject them);
    * norm clip: finite rows are scaled by ``min(1, clip/‖δ‖)`` — folded
      into the weight, the deltas themselves are untouched;
    * staleness: ``(1 + Δτ)^{-power}`` down-weighting and the optional hard
      cap Δτ ≤ ``staleness_cap``.

    Every defense is a pure per-row scalar, so the weights compose with any
    float participation mask and ride the same fused aggregation kernels.
    """
    rows = staleness.shape[0]
    w = jnp.ones((rows,), jnp.float32)
    out = deltas
    if guards.quarantine:
        ok = finite_rows(deltas)
        w = w * ok.astype(jnp.float32)

        def zap(d):
            m = ok.reshape((-1,) + (1,) * (d.ndim - 1))
            return jnp.where(m, d, jnp.zeros_like(d))

        out = jax.tree_util.tree_map(zap, deltas)
    if guards.clip_norm is not None:
        n = update_norms(deltas)
        w = w * jnp.minimum(1.0, guards.clip_norm / jnp.maximum(n, 1e-30))
    if guards.staleness_power != 0.0:
        s = staleness.astype(jnp.float32)
        w = w * (1.0 + jnp.maximum(s, 0.0)) ** (-guards.staleness_power)
    if guards.staleness_cap is not None:
        w = w * (staleness <= guards.staleness_cap).astype(jnp.float32)
    return w, out


def guarded_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                      num_clients, staleness: jax.Array, guards,
                      use_pallas: bool | None = None) -> Any:
    """Eq. (3) with server-side defenses: x ← x + (1/K) Σ_k m_k·g_k·δ_k.

    ``guards=None`` (or an all-off config) routes straight to
    :func:`masked_aggregate` — bit-identical to the undefended path.  On TPU
    the quarantine runs *inside* the fused kernel
    (``kernels.ops.fl_aggregate_guarded``: non-finite elements are zeroed in
    VMEM, no sanitized [K, D] copy is ever materialized in HBM).
    """
    if guards is None or not guards.active:
        return masked_aggregate(global_params, deltas, mask, num_clients,
                                use_pallas=use_pallas)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    w, safe = guard_weights(deltas, staleness, guards)
    m = mask.astype(jnp.float32) * w
    if use_pallas:
        from ..kernels import ops
        inv = 1.0 / jnp.asarray(num_clients, jnp.float32)

        def agg_k(g, d):
            out = ops.fl_aggregate_guarded(g.reshape(-1),
                                           d.reshape(d.shape[0], -1),
                                           m * inv)
            return out.reshape(g.shape).astype(g.dtype)

        # the kernel zeroes non-finite elements itself — pass raw deltas
        return jax.tree_util.tree_map(agg_k, global_params, deltas)
    return masked_aggregate(global_params, safe, m, num_clients,
                            use_pallas=False)


def guarded_subset_aggregate(global_params: Any, deltas_p: Any,
                             valid: jax.Array, num_clients,
                             staleness_p: jax.Array, guards,
                             use_pallas: bool | None = None) -> Any:
    """Participant-subset form of :func:`guarded_aggregate` (sparse path):
    rows are the padded transmitting bucket, ``num_clients`` may be traced."""
    if guards is None or not guards.active:
        return subset_aggregate(global_params, deltas_p, valid, num_clients,
                                use_pallas=use_pallas)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    w, safe = guard_weights(deltas_p, staleness_p, guards)
    v = valid.astype(jnp.float32) * w
    if use_pallas:
        from ..kernels import ops
        inv = 1.0 / jnp.asarray(num_clients, jnp.float32)

        def agg_k(g, d):
            out = ops.fl_aggregate_guarded(g.reshape(-1),
                                           d.reshape(d.shape[0], -1),
                                           v * inv)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas_p)
    return subset_aggregate(global_params, safe, v, num_clients,
                            use_pallas=False)


# ---------------------------------------------------------------------------
# pluggable staleness-aware aggregators (the competing async-FL schemes)
#
# The paper's eq.-3 update weighs every delivered pseudo-gradient by 1/K.
# The related-work baselines replace that constant with per-update weights
# built from staleness Δτ, the scheme's selection probability, or the update's
# age — expressed here as one branch-free weight program over *traced*
# parameters (AggParams), so a whole scheme panel can share a single compiled
# simulation with the scheme on a vmap axis (fl/schemes.run_scheme_matrix).
# All baselines are delta-form adaptations: x ← x + Σ_k a_k·δ_k, where the
# a_k of the normalized kinds sum to the mixing rate α over the delivered
# set (docs/schemes.md spells out each scheme's a_k).
# ---------------------------------------------------------------------------

_AGG_KINDS = ("paper", "fedasync", "csmaafl", "age")
_STALENESS_FNS = ("constant", "hinge", "poly")


class AggParams(NamedTuple):
    """Traced counterparts of :class:`AggregatorConfig` — a pytree of f32
    scalars.  The one-hot ``kind_*`` / ``sfn_*`` lanes make the weight
    program branch-free, so stacking AggParams along a leading axis and
    vmapping sweeps *schemes* in one device program (the same trick
    :class:`repro.fl.faults.FaultParams` plays for failure severities)."""

    kind_paper: jax.Array
    kind_fedasync: jax.Array
    kind_csmaafl: jax.Array
    kind_age: jax.Array
    sfn_constant: jax.Array
    sfn_hinge: jax.Array
    sfn_poly: jax.Array
    mix: jax.Array
    hinge_a: jax.Array
    hinge_b: jax.Array
    poly_a: jax.Array
    age_a: jax.Array
    prob_floor: jax.Array


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    """Staleness-aware aggregation scheme (frozen ⇒ usable in jitted
    closures; it shapes the program, :meth:`params` carries the math).

    Kinds:

    * ``"paper"`` — eq. 3 verbatim: a_k = m_k / K (the reproduction's
      default; ``SimConfig.aggregator=None`` keeps the byte-identical
      legacy program, ``kind="paper"`` runs the same math through the
      weighted path so it can share a vmapped scheme axis).
    * ``"fedasync"`` — FedAsync-style staleness-attenuated mixing
      (arXiv:1903.03934): raw weight s(Δτ_k), normalized over the delivered
      set and scaled by the server mixing rate α (``mix``) — the delta-form
      reading of averaging the per-client mixed models
      ``(1−α_t)x + α_t x_k`` with ``α_t = α·s(Δτ)``.
    * ``"csmaafl"`` — CSMAAFL-style aggregation (arXiv:2306.01207):
      contention-based scheduling makes participation non-uniform, so the
      delivered updates are importance-weighted by the inverse selection
      probability, raw = s(Δτ_k)/max(p_k, prob_floor), normalized, scaled
      by α — debiasing what the channel-aware contention skewed.
    * ``"age"`` — Hu–Chen–Larsson age-aware weighting (arXiv:2212.07356):
      raw = (1 + Δτ_k)^{+age_a} — updates from long-unheard clients count
      *more*, equalizing each client's effective footprint on the global
      model when the scheduler (``age_aware_policy``) cannot serve everyone.

    ``staleness_fn`` picks s(Δτ) for the fedasync/csmaafl kinds:
    ``"constant"`` (1), ``"hinge"`` (1 for Δτ ≤ b, else 1/(a·(Δτ−b))) or
    ``"poly"`` ((1+Δτ)^{−a}) — the three FedAsync variants.
    """

    kind: str = "paper"
    staleness_fn: str = "constant"
    mix: float = 0.6           # α — server mixing rate of the normalized kinds
    hinge_a: float = 10.0
    hinge_b: float = 4.0
    poly_a: float = 0.5
    age_a: float = 0.5
    prob_floor: float = 1e-2   # csmaafl importance-weight clamp (forced or
                               # near-zero-probability uploads stay bounded)

    def __post_init__(self):
        if self.kind not in _AGG_KINDS:
            raise ValueError(f"unknown aggregator kind {self.kind!r} "
                             f"(expected one of {_AGG_KINDS})")
        if self.staleness_fn not in _STALENESS_FNS:
            raise ValueError(f"unknown staleness_fn {self.staleness_fn!r} "
                             f"(expected one of {_STALENESS_FNS})")

    def params(self) -> AggParams:
        """The traced-parameter view (everything a vmap axis may sweep)."""
        return AggParams(
            kind_paper=jnp.float32(self.kind == "paper"),
            kind_fedasync=jnp.float32(self.kind == "fedasync"),
            kind_csmaafl=jnp.float32(self.kind == "csmaafl"),
            kind_age=jnp.float32(self.kind == "age"),
            sfn_constant=jnp.float32(self.staleness_fn == "constant"),
            sfn_hinge=jnp.float32(self.staleness_fn == "hinge"),
            sfn_poly=jnp.float32(self.staleness_fn == "poly"),
            mix=jnp.float32(self.mix),
            hinge_a=jnp.float32(self.hinge_a),
            hinge_b=jnp.float32(self.hinge_b),
            poly_a=jnp.float32(self.poly_a),
            age_a=jnp.float32(self.age_a),
            prob_floor=jnp.float32(self.prob_floor),
        )


def staleness_scale(staleness: jax.Array, ap: AggParams) -> jax.Array:
    """FedAsync's s(Δτ) per row, branch-free over the one-hot ``sfn_*``
    selector: constant 1, hinge ``1/(a·(Δτ−b))`` past the knee, or
    polynomial ``(1+Δτ)^{−a}``.  Always finite and positive for Δτ ≥ 0."""
    s = jnp.maximum(staleness.astype(jnp.float32), 0.0)
    hinge = jnp.where(s <= ap.hinge_b, 1.0,
                      1.0 / jnp.maximum(ap.hinge_a * (s - ap.hinge_b), 1e-6))
    poly = (1.0 + s) ** (-ap.poly_a)
    return ap.sfn_constant * 1.0 + ap.sfn_hinge * hinge + ap.sfn_poly * poly


def scheme_weights(mask: jax.Array, staleness: jax.Array, probs: jax.Array,
                   ap: AggParams, num_clients) -> jax.Array:
    """Per-row delta weights a_k of the configured aggregation scheme.

    ``mask`` is the effective delivery mask (``{0,1}`` decisions, possibly
    scaled by guard weights), ``staleness`` the per-row Δτ at transmission
    time, ``probs`` the policy's selection probabilities (the csmaafl
    importance weight divides by them), ``num_clients`` the population size
    (may be traced — the sparse path's bucket program passes it that way).

    Invariants (the property tests pin them): weights are finite and
    non-negative for any finite non-negative inputs; for the normalized
    kinds, ``Σ a_k = mix`` whenever any delivered mass exists (0 when the
    round delivered nothing); for the paper kind, ``a_k = m_k / K``.
    """
    m = mask.astype(jnp.float32)
    s = staleness_scale(staleness, ap)
    raw_age = (1.0 + jnp.maximum(staleness.astype(jnp.float32), 0.0)) \
        ** ap.age_a
    inv_p = 1.0 / jnp.maximum(probs.astype(jnp.float32), ap.prob_floor)
    raw = (ap.kind_paper * 1.0
           + ap.kind_fedasync * s
           + ap.kind_csmaafl * s * inv_p
           + ap.kind_age * raw_age)
    mraw = m * raw
    norm = mraw / jnp.maximum(jnp.sum(mraw), 1e-30)
    a_paper = m / jnp.asarray(num_clients, jnp.float32)
    return ap.kind_paper * a_paper + (1.0 - ap.kind_paper) * ap.mix * norm


def weighted_aggregate(global_params: Any, deltas: Any, weights: jax.Array,
                       use_pallas: bool | None = None) -> Any:
    """Generic weighted update: x ← x + Σ_r a_r·δ_r.

    The row axis may be the population (dense engine) or the participant
    bucket (sparse phase B) — the weights carry the masking, the 1/K (or
    normalization), and any guard scaling.  On TPU this is the fused
    ``kernels.ops.fl_aggregate_guarded`` kernel (it computes exactly this
    weighted sum, zeroing non-finite elements in VMEM); elsewhere the jnp
    path is the oracle.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate_guarded(g.reshape(-1),
                                           d.reshape(d.shape[0], -1),
                                           weights.astype(jnp.float32))
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas)

    def agg(g, d):
        a = weights.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * a, axis=0)

    return jax.tree_util.tree_map(agg, global_params, deltas)


def scheme_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                     num_clients, staleness: jax.Array, probs: jax.Array,
                     agg, guards=None, use_pallas: bool | None = None) -> Any:
    """Population-row aggregation under a pluggable scheme (+ optional
    guards).

    ``agg`` is an :class:`AggregatorConfig` or its traced :class:`AggParams`;
    guard weights (quarantine / norm clip / staleness defenses) fold into the
    mask before the scheme weights are computed, so defenses compose with
    every aggregation scheme exactly as they do with the paper's eq. 3.
    """
    ap = agg.params() if isinstance(agg, AggregatorConfig) else agg
    m = mask.astype(jnp.float32)
    safe = deltas
    if guards is not None and guards.active:
        gw, safe = guard_weights(deltas, staleness, guards)
        m = m * gw
    a = scheme_weights(m, staleness, probs, ap, num_clients)
    return weighted_aggregate(global_params, safe, a, use_pallas=use_pallas)


def scheme_subset_aggregate(global_params: Any, deltas_p: Any,
                            valid: jax.Array, num_clients,
                            staleness_p: jax.Array, probs_p: jax.Array,
                            agg, guards=None,
                            use_pallas: bool | None = None) -> Any:
    """Participant-subset form of :func:`scheme_aggregate` (sparse phase B):
    rows are the padded transmitting bucket and ``num_clients`` may be a
    traced scalar, so one compiled bucket program serves every population
    *and* every aggregation scheme (AggParams ride a vmap axis)."""
    return scheme_aggregate(global_params, deltas_p, valid, num_clients,
                            staleness_p, probs_p, agg, guards=guards,
                            use_pallas=use_pallas)


def broadcast_to_participants(state: FLState, new_global: Any,
                              mask: jax.Array) -> FLState:
    """Protocol Step 5: participants receive x_t (both x_k and y_k reset)."""
    def sel(stacked, g):
        m = mask.reshape((-1,) + (1,) * (g.ndim)).astype(bool)
        return jnp.where(m, g[None], stacked)

    client = jax.tree_util.tree_map(sel, state.client_params, new_global)
    anchor = jax.tree_util.tree_map(sel, state.anchor_params, new_global)
    last_tx = jnp.where(mask.astype(bool), state.round, state.last_tx)
    return state._replace(global_params=new_global, client_params=client,
                          anchor_params=anchor, round=state.round + 1,
                          last_tx=last_tx)
