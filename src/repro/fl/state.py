"""FL state containers.

``FLState`` holds the server's global model plus the *stacked* per-client
states (leading axis K): each client's divergent local model ``x_k`` and its
anchor ``y_k`` — the last global model it received (paper eq. 2).  Stacking
makes the whole protocol a handful of vmapped/einsummed pytree ops, and at
mega-scale the same leading axis becomes the data-parallel mesh axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class FLState(NamedTuple):
    global_params: Any   # pytree, the server's x_t
    client_params: Any   # pytree with leading K axis, x_{k,t}
    anchor_params: Any   # pytree with leading K axis, y_{k,t}
    round: jax.Array     # int32 scalar
    last_tx: jax.Array   # [K] int32, round of last transmission (staleness)


def replicate(params: Any, k: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), params)


def init_fl_state(params: Any, num_clients: int) -> FLState:
    stacked = replicate(params, num_clients)
    return FLState(
        global_params=params,
        client_params=stacked,
        anchor_params=stacked,
        round=jnp.zeros((), jnp.int32),
        last_tx=jnp.zeros((num_clients,), jnp.int32),
    )


def pseudo_gradients(state: FLState) -> Any:
    """Eq. (2): δ_k = x_k − y_k (stacked over clients)."""
    return jax.tree_util.tree_map(lambda c, a: c - a,
                                  state.client_params, state.anchor_params)


def masked_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                     num_clients: int, use_pallas: bool | None = None) -> Any:
    """Eq. (3): x ← x + (1/K) Σ_{k∈C_t} δ_k.

    ``use_pallas=None`` auto-selects by backend: on TPU every leaf routes
    through the fused ``kernels.fl_aggregate`` kernel (the op sits on the hot
    path of the scan engine, one HBM pass per tile); elsewhere the jnp path is
    both the oracle and the fastest option.  ``True``/``False`` force a path
    (``True`` off-TPU runs the kernel in interpret mode — for parity tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate(g.reshape(-1),
                                   d.reshape(d.shape[0], -1),
                                   mask.astype(jnp.float32), use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas)

    def agg(g, d):
        m = mask.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / num_clients

    return jax.tree_util.tree_map(agg, global_params, deltas)


def subset_aggregate(global_params: Any, deltas_p: Any, valid: jax.Array,
                     num_clients, use_pallas: bool | None = None) -> Any:
    """Participant-subset eq. (3): x ← x + (1/K) Σ_p valid_p · δ_p.

    ``deltas_p`` carries a leading *participant bucket* axis P (the gathered
    transmitting set, padded), not the population axis K; ``valid`` masks the
    padding lanes and ``num_clients`` is the population size the paper's
    1/K averaging divides by — it may be a **traced** scalar, which is what
    lets one compiled sparse round step serve every population sharing a
    bucket.  Backend dispatch matches :func:`masked_aggregate`: the fused
    Pallas kernel on TPU (subset form — see ``kernels.ops.fl_aggregate_subset``),
    the jnp oracle elsewhere.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kf = jnp.asarray(num_clients, jnp.float32)
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate_subset(
                g.reshape(-1), d.reshape(d.shape[0], -1),
                valid.astype(jnp.float32), kf, use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas_p)

    def agg(g, d):
        m = valid.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / kf

    return jax.tree_util.tree_map(agg, global_params, deltas_p)


def finite_rows(deltas: Any) -> jax.Array:
    """Per-row (client/participant) finiteness of a stacked delta pytree:
    ``[R] bool``, False when *any* element of the row, in any leaf, is
    NaN/Inf."""
    def leaf_ok(d):
        return jnp.all(jnp.isfinite(d).reshape(d.shape[0], -1), axis=1)

    oks = [leaf_ok(d) for d in jax.tree_util.tree_leaves(deltas)]
    out = oks[0]
    for o in oks[1:]:
        out = out & o
    return out


def update_norms(deltas: Any) -> jax.Array:
    """Per-row global L2 norm across every leaf of a stacked delta pytree
    (``[R] f32``).  Non-finite elements contribute 0 so the clip factor of a
    quarantined row stays well-defined (the row is rejected anyway)."""
    def leaf_sq(d):
        d = d.reshape(d.shape[0], -1).astype(jnp.float32)
        d = jnp.where(jnp.isfinite(d), d, 0.0)
        return jnp.sum(d * d, axis=1)

    sq = sum(leaf_sq(d) for d in jax.tree_util.tree_leaves(deltas))
    return jnp.sqrt(sq)


def guard_weights(deltas: Any, staleness: jax.Array, guards) -> tuple:
    """Defensive per-row weights + sanitized deltas for aggregation.

    ``guards`` is a :class:`repro.fl.faults.GuardConfig`.  Returns
    ``(weights [R] f32, deltas')`` where the effective aggregation mask is
    ``mask · weights``:

    * quarantine: non-finite rows get weight 0 **and** are zeroed in
      ``deltas'`` (``0 · NaN = NaN`` — masking alone cannot reject them);
    * norm clip: finite rows are scaled by ``min(1, clip/‖δ‖)`` — folded
      into the weight, the deltas themselves are untouched;
    * staleness: ``(1 + Δτ)^{-power}`` down-weighting and the optional hard
      cap Δτ ≤ ``staleness_cap``.

    Every defense is a pure per-row scalar, so the weights compose with any
    float participation mask and ride the same fused aggregation kernels.
    """
    rows = staleness.shape[0]
    w = jnp.ones((rows,), jnp.float32)
    out = deltas
    if guards.quarantine:
        ok = finite_rows(deltas)
        w = w * ok.astype(jnp.float32)

        def zap(d):
            m = ok.reshape((-1,) + (1,) * (d.ndim - 1))
            return jnp.where(m, d, jnp.zeros_like(d))

        out = jax.tree_util.tree_map(zap, deltas)
    if guards.clip_norm is not None:
        n = update_norms(deltas)
        w = w * jnp.minimum(1.0, guards.clip_norm / jnp.maximum(n, 1e-30))
    if guards.staleness_power != 0.0:
        s = staleness.astype(jnp.float32)
        w = w * (1.0 + jnp.maximum(s, 0.0)) ** (-guards.staleness_power)
    if guards.staleness_cap is not None:
        w = w * (staleness <= guards.staleness_cap).astype(jnp.float32)
    return w, out


def guarded_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                      num_clients, staleness: jax.Array, guards,
                      use_pallas: bool | None = None) -> Any:
    """Eq. (3) with server-side defenses: x ← x + (1/K) Σ_k m_k·g_k·δ_k.

    ``guards=None`` (or an all-off config) routes straight to
    :func:`masked_aggregate` — bit-identical to the undefended path.  On TPU
    the quarantine runs *inside* the fused kernel
    (``kernels.ops.fl_aggregate_guarded``: non-finite elements are zeroed in
    VMEM, no sanitized [K, D] copy is ever materialized in HBM).
    """
    if guards is None or not guards.active:
        return masked_aggregate(global_params, deltas, mask, num_clients,
                                use_pallas=use_pallas)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    w, safe = guard_weights(deltas, staleness, guards)
    m = mask.astype(jnp.float32) * w
    if use_pallas:
        from ..kernels import ops
        inv = 1.0 / jnp.asarray(num_clients, jnp.float32)

        def agg_k(g, d):
            out = ops.fl_aggregate_guarded(g.reshape(-1),
                                           d.reshape(d.shape[0], -1),
                                           m * inv)
            return out.reshape(g.shape).astype(g.dtype)

        # the kernel zeroes non-finite elements itself — pass raw deltas
        return jax.tree_util.tree_map(agg_k, global_params, deltas)
    return masked_aggregate(global_params, safe, m, num_clients,
                            use_pallas=False)


def guarded_subset_aggregate(global_params: Any, deltas_p: Any,
                             valid: jax.Array, num_clients,
                             staleness_p: jax.Array, guards,
                             use_pallas: bool | None = None) -> Any:
    """Participant-subset form of :func:`guarded_aggregate` (sparse path):
    rows are the padded transmitting bucket, ``num_clients`` may be traced."""
    if guards is None or not guards.active:
        return subset_aggregate(global_params, deltas_p, valid, num_clients,
                                use_pallas=use_pallas)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    w, safe = guard_weights(deltas_p, staleness_p, guards)
    v = valid.astype(jnp.float32) * w
    if use_pallas:
        from ..kernels import ops
        inv = 1.0 / jnp.asarray(num_clients, jnp.float32)

        def agg_k(g, d):
            out = ops.fl_aggregate_guarded(g.reshape(-1),
                                           d.reshape(d.shape[0], -1),
                                           v * inv)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas_p)
    return subset_aggregate(global_params, safe, v, num_clients,
                            use_pallas=False)


def broadcast_to_participants(state: FLState, new_global: Any,
                              mask: jax.Array) -> FLState:
    """Protocol Step 5: participants receive x_t (both x_k and y_k reset)."""
    def sel(stacked, g):
        m = mask.reshape((-1,) + (1,) * (g.ndim)).astype(bool)
        return jnp.where(m, g[None], stacked)

    client = jax.tree_util.tree_map(sel, state.client_params, new_global)
    anchor = jax.tree_util.tree_map(sel, state.anchor_params, new_global)
    last_tx = jnp.where(mask.astype(bool), state.round, state.last_tx)
    return state._replace(global_params=new_global, client_params=client,
                          anchor_params=anchor, round=state.round + 1,
                          last_tx=last_tx)
