"""FL state containers.

``FLState`` holds the server's global model plus the *stacked* per-client
states (leading axis K): each client's divergent local model ``x_k`` and its
anchor ``y_k`` — the last global model it received (paper eq. 2).  Stacking
makes the whole protocol a handful of vmapped/einsummed pytree ops, and at
mega-scale the same leading axis becomes the data-parallel mesh axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class FLState(NamedTuple):
    global_params: Any   # pytree, the server's x_t
    client_params: Any   # pytree with leading K axis, x_{k,t}
    anchor_params: Any   # pytree with leading K axis, y_{k,t}
    round: jax.Array     # int32 scalar
    last_tx: jax.Array   # [K] int32, round of last transmission (staleness)


def replicate(params: Any, k: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape), params)


def init_fl_state(params: Any, num_clients: int) -> FLState:
    stacked = replicate(params, num_clients)
    return FLState(
        global_params=params,
        client_params=stacked,
        anchor_params=stacked,
        round=jnp.zeros((), jnp.int32),
        last_tx=jnp.zeros((num_clients,), jnp.int32),
    )


def pseudo_gradients(state: FLState) -> Any:
    """Eq. (2): δ_k = x_k − y_k (stacked over clients)."""
    return jax.tree_util.tree_map(lambda c, a: c - a,
                                  state.client_params, state.anchor_params)


def masked_aggregate(global_params: Any, deltas: Any, mask: jax.Array,
                     num_clients: int, use_pallas: bool | None = None) -> Any:
    """Eq. (3): x ← x + (1/K) Σ_{k∈C_t} δ_k.

    ``use_pallas=None`` auto-selects by backend: on TPU every leaf routes
    through the fused ``kernels.fl_aggregate`` kernel (the op sits on the hot
    path of the scan engine, one HBM pass per tile); elsewhere the jnp path is
    both the oracle and the fastest option.  ``True``/``False`` force a path
    (``True`` off-TPU runs the kernel in interpret mode — for parity tests).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate(g.reshape(-1),
                                   d.reshape(d.shape[0], -1),
                                   mask.astype(jnp.float32), use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas)

    def agg(g, d):
        m = mask.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / num_clients

    return jax.tree_util.tree_map(agg, global_params, deltas)


def subset_aggregate(global_params: Any, deltas_p: Any, valid: jax.Array,
                     num_clients, use_pallas: bool | None = None) -> Any:
    """Participant-subset eq. (3): x ← x + (1/K) Σ_p valid_p · δ_p.

    ``deltas_p`` carries a leading *participant bucket* axis P (the gathered
    transmitting set, padded), not the population axis K; ``valid`` masks the
    padding lanes and ``num_clients`` is the population size the paper's
    1/K averaging divides by — it may be a **traced** scalar, which is what
    lets one compiled sparse round step serve every population sharing a
    bucket.  Backend dispatch matches :func:`masked_aggregate`: the fused
    Pallas kernel on TPU (subset form — see ``kernels.ops.fl_aggregate_subset``),
    the jnp oracle elsewhere.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kf = jnp.asarray(num_clients, jnp.float32)
    if use_pallas:
        from ..kernels import ops

        def agg_k(g, d):
            out = ops.fl_aggregate_subset(
                g.reshape(-1), d.reshape(d.shape[0], -1),
                valid.astype(jnp.float32), kf, use_pallas=True)
            return out.reshape(g.shape).astype(g.dtype)

        return jax.tree_util.tree_map(agg_k, global_params, deltas_p)

    def agg(g, d):
        m = valid.astype(d.dtype).reshape((-1,) + (1,) * (d.ndim - 1))
        return g + jnp.sum(d * m, axis=0) / kf

    return jax.tree_util.tree_map(agg, global_params, deltas_p)


def broadcast_to_participants(state: FLState, new_global: Any,
                              mask: jax.Array) -> FLState:
    """Protocol Step 5: participants receive x_t (both x_k and y_k reset)."""
    def sel(stacked, g):
        m = mask.reshape((-1,) + (1,) * (g.ndim)).astype(bool)
        return jnp.where(m, g[None], stacked)

    client = jax.tree_util.tree_map(sel, state.client_params, new_global)
    anchor = jax.tree_util.tree_map(sel, state.anchor_params, new_global)
    last_tx = jnp.where(mask.astype(bool), state.round, state.last_tx)
    return state._replace(global_params=new_global, client_params=client,
                          anchor_params=anchor, round=state.round + 1,
                          last_tx=last_tx)
