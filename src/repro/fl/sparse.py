"""Participant-centric sparse rounds: per-participant cost at any population.

The dense scan engine (:mod:`repro.fl.engine`) carries every per-round
structure at population width ``[K]`` — client batch gather, local training,
``[K, D]`` deltas — so simulation cost scales with the population even though
only ~pK clients transmit per round.  This module restructures the round
transition so the expensive work scales with the *transmitting set*:

* **Phase A — participation program** (``build_participation_program``):
  a tiny jitted scan over ``[K]`` *vectors only* (probabilities, Bernoulli
  draws, Δ_k staleness dynamics, the eq.-5 energy ledger).  It shares
  :func:`~repro.fl.engine.apply_round_decision` with the dense engine, so
  masks and energies are bit-identical; its outputs are *participant-sized*:
  the transmitting index set per round (padded to a static bucket), each
  participant's anchor slot, and its energy.  Compiled per K, but the
  program is a few K-length vector ops per round — microseconds, not the
  K·D local-training cost.
* **Batch gather** (:func:`repro.data.device.gather_participant_rounds`):
  participants' minibatches come from the per-client stream
  ``fold_in(fold_in(data_key, t), k)``, so only ``[T, P, L, B, ...]`` is
  ever gathered from the resident store — no ``[K, L, B, ...]`` round batch
  exists anywhere.
* **Phase B — training program** (``build_sparse_train_program``): a jitted
  scan whose shapes depend only on ``(bucket, T, model)`` — **never on K**.
  The carry is a global-model *history* ``[T+1, D]`` (slot s = the model
  broadcast after round s-1); each round gathers its participants' anchors
  ``hist[slot_p]``, runs local SGD over the ``[P, ...]`` bucket, and applies
  the participant-subset eq.-3 update (:func:`repro.fl.state.subset_aggregate`,
  Pallas-fused on TPU) with the population size as a *traced* scalar.  One
  compile serves every K sharing a bucket — the fix for the engine's
  one-compile-per-K limitation (``TRAIN_TRACE_COUNT`` counts traces; the
  K-sweep test pins it to one).

Semantics: the sparse path implements ``SimConfig.local_mode =
"participants"`` — a client trains ``local_iters`` steps from its last
received global *in the round it transmits* (the standard sampled-FedAvg
reading of the paper's protocol).  The dense engine supports the same mode,
and the two are parity-tested against each other; the paper's default
``"continuous"`` mode (every client trains every round) is irreducibly
O(K·T) compute and keeps the dense path.

Memory: phase B replaces the dense ``[K, D]`` client/anchor stacks with the
``[T+1, D]`` history — a win whenever K ≫ T.  The dense ``[K]`` ledgers
(energy, last_tx) survive in phase A and shard over a mesh via
``launch.sharding.ledger_shardings``.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.channel import CellConfig
from ..core.selection import (as_policy_fn, participant_bucket,
                              participants_from_mask)
from ..data.device import (DeviceDataStore, data_stream_key,
                           from_client_datasets, gather_participant_rounds)
from ..data.synthetic import Dataset
from ..obs.taps import (MetricsState, init_metrics, merge_metrics,
                        metrics_active, update_train_taps)
from ..obs.telemetry import emit_run_manifest, get_telemetry
from ..optim import Optimizer, sgd
from .faults import apply_faults, corrupt_deltas, init_fault_state
from .state import (FLState, guarded_subset_aggregate,
                    scheme_subset_aggregate, subset_aggregate)

#: number of times the participant-shaped training program has been traced.
#: Shapes depend only on (bucket, T, model), so a K-sweep sharing a bucket
#: must not bump this more than once (tests/test_sparse_engine.py).
TRAIN_TRACE_COUNT = 0


def train_trace_count() -> int:
    return TRAIN_TRACE_COUNT


#: process-wide one-shot flag for the bucket-spill warning (a long sweep
#: that overflows every call should not drown the log).
_SPILL_WARNED = False


def _warn_spill_once(bucket: int, grown: int, realized: int) -> None:
    global _SPILL_WARNED
    if _SPILL_WARNED:
        return
    _SPILL_WARNED = True
    warnings.warn(
        f"participant bucket overflow: a round realized {realized} "
        f"transmitters > bucket {bucket}; spilling — regrowing the bucket "
        f"to {grown} and rerunning phase A (exact, but recompiles phase A "
        "and the training program). Pass SimConfig(participant_bucket=...) "
        "with more headroom, or overflow='error' to fail instead.",
        RuntimeWarning, stacklevel=3)


class _DecisionView(NamedTuple):
    """The two FLState fields :func:`apply_round_decision` actually reads —
    phase A never materializes client/anchor parameter stacks."""

    round: jax.Array    # int32 scalar
    last_tx: jax.Array  # [K] int32


class ParticipationTrace(NamedTuple):
    """Phase A per-round outputs (leading axis T after the scan) — all
    participant-sized except the scalar overflow counter.  Compaction is
    always over the *decision* mask (autonomous Bernoulli draws + Δ_k
    forcing); the fault pipeline's outcomes ride along per participant, so
    phase B can drop lost uploads and corrupt/guard the delivered ones
    without any [K]-shaped array."""

    part_idx: jax.Array     # [P] int32 transmitting ids, padded with K
    valid: jax.Array        # [P] bool
    anchor_slot: jax.Array  # [P] int32 history slot of each anchor
    e_p: jax.Array          # [P] f32 Joules (eq. 5, incl. retry energy)
    delivered: jax.Array    # [P] bool — upload survived the fault pipeline
    corrupt: jax.Array      # [P] bool — delivered but adversarially mangled
    stale: jax.Array        # [P] int32 staleness Δτ at transmission time
    prob: jax.Array         # [P] f32 nominal policy prob (pre aging-boost)
    n_tx: jax.Array         # int32 realized transmitter count (overflow check)
    # metrics-tap lanes, emitted only when cfg.metrics enables ledger taps
    # (the ledger accumulators reduce over these post-scan — no per-round
    # [K]-vector tap work rides in the sequential scan)
    forced_p: Any = None    # [P] bool — Δ_k-forced transmission
    base_p: Any = None      # [P] f32 — decision energy before faults


def _reduce_ledger_taps(tr: ParticipationTrace, spec, num_clients: int,
                        rounds: int) -> MetricsState:
    """Batched post-scan reduction of the ledger taps from the ``[T, P]``
    trace lanes — one scatter/sum pass instead of per-round accumulator ops
    inside the sequential scan (which costs ~20% on the tiny-model sparse
    path, where phase A dominates).

    The pad sentinel ``K`` in ``part_idx`` is out of bounds, so
    ``mode="drop"`` scatters discard padded lanes; integer taps stay
    bit-exact with the dense engine's in-scan accumulation (participants
    are exactly the mask fires — the runner hard-errors on bucket
    overflow).  Float energy sums change association order only.
    """
    tx = stale = ec = None
    if spec.participation:
        tx = jnp.zeros((num_clients,), jnp.int32).at[tr.part_idx.ravel()].add(
            tr.valid.ravel().astype(jnp.int32), mode="drop")
    if spec.staleness_hist:
        b = jnp.clip(tr.stale.astype(jnp.int32), 0, spec.staleness_bins - 1)
        stale = jnp.zeros((spec.staleness_bins,), jnp.int32).at[b.ravel()].add(
            tr.delivered.ravel().astype(jnp.int32), mode="drop")
    if spec.energy_by_cause:
        e = tr.e_p.astype(jnp.float32)          # 0 on padded lanes
        f = tr.forced_p.astype(jnp.float32)
        retry = jnp.maximum(e - tr.base_p.astype(jnp.float32), 0.0)
        ec = jnp.stack([jnp.sum(e * (1.0 - f)), jnp.sum(e * f),
                        jnp.sum(retry)])
    return MetricsState(tx_count=tx, stale_hist=stale, energy_cause=ec,
                        rounds=jnp.asarray(rounds, jnp.int32))


def build_participation_program(policy_fn, cfg, cell: CellConfig,
                                num_clients: int, bucket: int,
                                hoist_rounds: bool | None = None) -> Callable:
    """Phase A: ``(h_rounds [T, K], base_key) -> (last_tx [K], energy [K],
    ParticipationTrace[T])``.

    Pure ``[K]``-vector work per round; the policy must be ``state_free``
    (all five paper schemes are) or a *ledger* policy reading only the
    ``(round, last_tx)`` staleness ledger that phase A already carries —
    state_free policies hoist to one vmap over the horizon, ledger policies
    run inside the scan step against the :class:`_DecisionView`.  Decision
    math is byte-for-byte the dense engine's ``apply_round_decision`` on the
    identical ``fold_in(base_key, t)`` stream, so realized masks and the
    energy ledger match the dense scan bit-wise.

    **Full round hoist**: when the decision itself is round-local — a
    state_free policy, no fault processes (the Markov availability chain is
    sequential state) and no ``max_staleness`` forcing (Δ_k reads the
    ledger) — the serial scan over T disappears entirely: the whole
    ``[T, K]`` mask/energy matrix comes from one vmap over the horizon and
    the staleness/anchor ledgers are recovered with two exclusive
    ``cummax`` passes.  Masks, index sets, anchor slots, staleness and
    ``last_tx`` are bit-identical to the scanned path (pure integer
    recurrences); only the energy ledger's summation *order* changes
    (tolerance-level, like every cross-path energy comparison).
    ``hoist_rounds`` forces the choice for parity tests: ``True`` raises
    if the preconditions fail, ``False`` pins the serial scan, ``None``
    (default) auto-selects.
    """
    from .engine import apply_round_decision  # deferred: engine imports us

    hoist = getattr(policy_fn, "state_free", False)
    if not hoist and not getattr(policy_fn, "ledger", False):
        raise ValueError(
            "sparse participation requires a state_free or ledger policy "
            "(phase A carries only the (round, last_tx) ledger); policies "
            "reading trained parameters must use the dense engine")
    K = num_clients
    faults = cfg.faults
    fparams = faults.params() if faults is not None else None
    # ledger taps reduce post-scan from trace lanes (split accumulation: the
    # train taps live in phase B); guards are irrelevant to the ledger subset
    ltap = metrics_active(cfg.metrics, None, parts="ledger")
    full_hoist = hoist and faults is None and cfg.max_staleness is None
    if hoist_rounds is not None:
        if hoist_rounds and not full_hoist:
            raise ValueError(
                "hoist_rounds=True needs a state_free policy, faults=None "
                "and max_staleness=None (everything else carries sequential "
                "state through the round scan)")
        full_hoist = bool(hoist_rounds)

    def program(h_rounds, base_key):
        ts = jnp.arange(cfg.rounds, dtype=jnp.int32)
        if hoist:
            pw_all = jax.vmap(lambda t, h: policy_fn(t, h, None))(
                ts, h_rounds)
        else:  # ledger policy: dummy lanes, the policy runs in the step
            pw_all = (jnp.zeros((cfg.rounds, 0)),) * 2

        if full_hoist:
            zeros_ltx = jnp.zeros((K,), jnp.int32)

            def decide(t, h_t, probs, w):
                # the dummy view is never read: max_staleness is None (no
                # Δ_k forcing) and aging_boost gates on it too
                view = _DecisionView(round=t, last_tx=zeros_ltx)
                return apply_round_decision(probs, w, t, h_t, view,
                                            base_key, cfg, cell, K)

            mask_all, _, _, e_all = jax.vmap(decide)(
                ts, h_rounds, pw_all[0], pw_all[1])
            fire = mask_all > 0
            tsc = ts[:, None]
            # ledger recurrences as exclusive cumulative maxima: last_tx
            # before round t = max{s < t : client fired at s} (0 if none),
            # anchor slot before round t = that + 1 (0 if none) — identical
            # integers to the scanned where(delivered, t, ...) updates
            lt_inc = jax.lax.cummax(jnp.where(fire, tsc, 0), axis=0)
            lt_excl = jnp.concatenate(
                [jnp.zeros((1, K), jnp.int32), lt_inc[:-1]], axis=0)
            slot_inc = jax.lax.cummax(jnp.where(fire, tsc + 1, 0), axis=0)
            slot_excl = jnp.concatenate(
                [jnp.zeros((1, K), jnp.int32), slot_inc[:-1]], axis=0)

            def compact(t, mask, e_round, probs, lt_prev, slot_prev):
                idx, valid, n_tx = participants_from_mask(mask, bucket)
                kc = jnp.clip(idx, 0, K - 1)
                e_p = jnp.where(valid, e_round[kc], 0.0)
                tr = ParticipationTrace(
                    idx, valid,
                    jnp.where(valid, slot_prev[kc], 0), e_p,
                    valid, jnp.zeros((bucket,), bool),
                    jnp.where(valid, t - lt_prev[kc], 0),
                    jnp.where(valid, probs.astype(jnp.float32)[kc], 0.0),
                    n_tx)
                if ltap:   # no forcing, no faults: e_base == e_round
                    tr = tr._replace(forced_p=jnp.zeros((bucket,), bool),
                                     base_p=e_p)
                return tr

            tr = jax.vmap(compact)(ts, mask_all, e_all, pw_all[0],
                                   lt_excl, slot_excl)
            energy = jnp.sum(e_all, axis=0)
            if ltap:
                return lt_inc[-1], energy, tr, _reduce_ledger_taps(
                    tr, cfg.metrics, K, cfg.rounds)
            return lt_inc[-1], energy, tr

        def step(carry, xs):
            last_tx, anchor_slot, energy = carry[0], carry[1], carry[2]
            if faults is not None:
                fstate = carry[3]
            t, h_t, probs, w = xs
            view = _DecisionView(round=t, last_tx=last_tx)
            if not hoist:
                probs, w = policy_fn(t, h_t, view)
            mask, forced, w, e_round = apply_round_decision(
                probs, w, t, h_t, view, base_key, cfg, cell, K)
            e_base = e_round        # decision energy before the fault pipeline
            # fault pipeline on the same salted streams as the dense engine:
            # masks above stay untouched, only delivery/energy change
            if faults is not None:
                out, fstate = apply_faults(t, base_key, mask, e_round,
                                           fstate, fparams, faults)
                delivered, corrupt, e_round = (out.delivered, out.corrupt,
                                               out.e_round)
            else:
                delivered = mask
                corrupt = jnp.zeros((K,), bool)
            energy = energy + e_round
            idx, valid, n_tx = participants_from_mask(mask, bucket)
            kc = jnp.clip(idx, 0, K - 1)
            slot_p = jnp.where(valid, anchor_slot[kc], 0)
            e_p = jnp.where(valid, e_round[kc], 0.0)
            del_p = valid & (delivered[kc] > 0)
            cor_p = valid & corrupt[kc]
            stale_p = jnp.where(valid, t - last_tx[kc], 0)
            prob_p = jnp.where(valid, probs.astype(jnp.float32)[kc], 0.0)
            # the server's ledgers advance on *delivered* uploads (the dense
            # engine broadcasts to the delivered set) — a lost upload leaves
            # last_tx/anchor untouched, so its staleness keeps growing
            last_tx = jnp.where(delivered > 0, t, last_tx)
            anchor_slot = jnp.where(delivered > 0, t + 1, anchor_slot)
            carry = (last_tx, anchor_slot, energy)
            if faults is not None:
                carry = carry + (fstate,)
            tr = ParticipationTrace(idx, valid, slot_p, e_p, del_p, cor_p,
                                    stale_p, prob_p, n_tx)
            if ltap:
                # ledger-tap lanes ride the trace instead of the carry: the
                # accumulators reduce over [T, P] post-scan, keeping the
                # sequential scan free of per-round [K]-vector tap work
                tr = tr._replace(forced_p=valid & (forced[kc] > 0),
                                 base_p=jnp.where(valid, e_base[kc], 0.0))
            return carry, tr

        carry0 = (jnp.zeros((K,), jnp.int32), jnp.zeros((K,), jnp.int32),
                  jnp.zeros((K,), jnp.float32))
        if faults is not None:
            carry0 = carry0 + (init_fault_state(K),)
        final, tr = jax.lax.scan(
            step, carry0, (ts, h_rounds, pw_all[0], pw_all[1]))
        if ltap:     # 4-tuple only when ledger taps materialize
            return final[0], final[2], tr, _reduce_ledger_taps(
                tr, cfg.metrics, K, cfg.rounds)
        return final[0], final[2], tr

    return program


# ---------------------------------------------------------------------------
# phase B: the K-independent participant training program
# ---------------------------------------------------------------------------

#: (bucket, T, model/cfg signature) -> jitted training program.  One compile
#: per bucket — populations of any size reuse the entry.
_TRAIN_CACHE: dict = {}


def _train_cache_key(cfg, opt_token, loss_fn, acc_fn, params, sample_shape,
                     test_shape, bucket: int):
    shapes = tuple((tuple(l.shape), str(l.dtype))
                   for l in jax.tree_util.tree_leaves(params))
    treedef = str(jax.tree_util.tree_structure(params))
    return (bucket, cfg.rounds, cfg.local_iters, cfg.batch_size,
            cfg.eval_every, opt_token, id(loss_fn), id(acc_fn), treedef,
            shapes, tuple(sample_shape), tuple(test_shape),
            repr(cfg.faults), repr(cfg.guards), repr(cfg.aggregator),
            repr(cfg.metrics))


def build_sparse_train_program(loss_fn: Callable, acc_fn: Callable,
                               opt: Optimizer, cfg) -> Callable:
    """Phase B: ``(params, xb [T,P,L,B,...], yb, valid [T,P], slot [T,P],
    num_clients, test_x, test_y[, delivered, corrupt, stale]) ->
    (global, (acc, loss, did_eval)[T])``.

    No array in this program carries a K-sized axis: the carry is the
    global-model history ``[T+1, D]``, training runs over the ``[P, ...]``
    bucket, and the 1/K averaging receives the population as a traced
    scalar.  Tracing it bumps :data:`TRAIN_TRACE_COUNT`.

    The trailing optional operands are the fault pipeline's per-participant
    outcomes from phase A: lost uploads aggregate with weight 0, corrupt
    flags drive :func:`~repro.fl.faults.corrupt_deltas`, and staleness feeds
    the defensive :func:`~repro.fl.state.guarded_subset_aggregate` when
    ``cfg.guards`` is active.  Omitted (the faults-off call) they default to
    ``delivered = valid`` / no corruption — the pre-robustness program.

    With ``cfg.aggregator`` set the update swaps to the pluggable scheme
    aggregation (:func:`~repro.fl.state.scheme_subset_aggregate`); phase A's
    nominal-prob lane rides in as ``probs_all`` and ``agg_params`` can be a
    traced :class:`~repro.fl.state.AggParams` (vmapped scheme panels).
    """
    from .engine import make_local_train  # deferred: engine imports us

    vtrain = make_local_train(loss_fn, opt)
    T = cfg.rounds
    faults = cfg.faults
    guards = cfg.guards
    agg = cfg.aggregator
    fparams = faults.params() if faults is not None else None
    # train taps (guard events / weight stats) accumulate over the [P]
    # bucket rows here; counts match the dense engine exactly, float
    # reductions to associativity (split accumulation with phase A)
    ttap = metrics_active(cfg.metrics, guards, parts="train")

    def program(params, xb_all, yb_all, valid_all, slot_all, num_clients,
                test_x, test_y, delivered_all=None, corrupt_all=None,
                stale_all=None, probs_all=None, agg_params=None):
        global TRAIN_TRACE_COUNT
        TRAIN_TRACE_COUNT += 1
        hist0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros((T + 1,) + p.shape, p.dtype).at[0].set(p),
            params)
        if delivered_all is None:
            delivered_all = valid_all
        if corrupt_all is None:
            corrupt_all = jnp.zeros(valid_all.shape, bool)
        if stale_all is None:
            stale_all = jnp.zeros(valid_all.shape, jnp.int32)
        if probs_all is None:
            probs_all = jnp.zeros(valid_all.shape, jnp.float32)
        ap = None
        if agg is not None:
            ap = agg.params() if agg_params is None else agg_params

        def eval_now(p):
            return (jnp.asarray(acc_fn(p, test_x, test_y), jnp.float32),
                    jnp.asarray(loss_fn(p, test_x, test_y), jnp.float32))

        def skip_eval(p):
            del p
            return jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)

        def step(carry, xs):
            hist = carry[0] if ttap else carry
            t, xb, yb, valid, slot, deliv, corr, stale, prob = xs
            g_t = jax.tree_util.tree_map(lambda h: h[t], hist)
            anchors = jax.tree_util.tree_map(lambda h: h[slot], hist)
            trained = vtrain(anchors, xb, yb)
            deltas = jax.tree_util.tree_map(lambda a, b: a - b, trained,
                                            anchors)
            if faults is not None:
                deltas = corrupt_deltas(deltas, corr, fparams, faults)
            if agg is not None:
                g_new = scheme_subset_aggregate(g_t, deltas, deliv,
                                                num_clients, stale, prob,
                                                ap, guards=guards)
            elif guards is not None and guards.active:
                g_new = guarded_subset_aggregate(g_t, deltas, deliv,
                                                 num_clients, stale, guards)
            else:
                g_new = subset_aggregate(g_t, deltas, deliv, num_clients)
            hist = jax.tree_util.tree_map(
                lambda h, g: h.at[t + 1].set(g), hist, g_new)
            do_eval = jnp.logical_or(t % cfg.eval_every == 0, t == T - 1)
            acc, loss = jax.lax.cond(do_eval, eval_now, skip_eval, g_new)
            if ttap:
                ms = update_train_taps(
                    carry[1], cfg.metrics, deltas=deltas, delivered=deliv,
                    staleness=stale, probs=prob, num_clients=num_clients,
                    guards=guards, agg_params=ap)
                return (hist, ms), (acc, loss, do_eval)
            return hist, (acc, loss, do_eval)

        ts = jnp.arange(T, dtype=jnp.int32)
        carry0 = ((hist0, init_metrics(cfg.metrics, 0, guards,
                                       parts="train"))
                  if ttap else hist0)
        final, traces = jax.lax.scan(
            step, carry0, (ts, xb_all, yb_all, valid_all, slot_all,
                           delivered_all, corrupt_all, stale_all, probs_all))
        hist = final[0] if ttap else final
        g_final = jax.tree_util.tree_map(lambda h: h[T], hist)
        if ttap:     # 3-tuple only when train taps materialize
            return g_final, traces, final[1]
        return g_final, traces

    return program


def _cached_train_program(key, builder: Callable) -> Callable:
    tel = get_telemetry()
    if key not in _TRAIN_CACHE:
        tel.inc("sparse.train_cache_miss")
        _TRAIN_CACHE[key] = jax.jit(builder())
    else:
        tel.inc("sparse.train_cache_hit")
    return _TRAIN_CACHE[key]


# ---------------------------------------------------------------------------
# runner: phase A -> participant gather -> phase B -> SimResult
# ---------------------------------------------------------------------------


def _auto_bucket(policy_fn, h_rounds, cfg, num_clients: int) -> int:
    """Bucket from the expected transmitting mass: max over rounds of Σp,
    with Poisson-tail headroom (see :func:`participant_bucket`).

    Ledger policies are probed at zero staleness (``state=None``) — their
    contract requires tolerating it; the Poisson-tail headroom absorbs the
    resulting estimate noise, and the spill path stays exact regardless.
    """
    ts = jnp.arange(cfg.rounds, dtype=jnp.int32)
    probs = jax.jit(jax.vmap(lambda t, h: policy_fn(t, h, None)[0]))(
        ts, h_rounds)
    expected = float(jnp.max(jnp.sum(probs, axis=-1)))
    return participant_bucket(expected, cap=num_clients)


def make_sparse_runner(loss_fn: Callable, acc_fn: Callable,
                       client_data: Sequence[Dataset], test_ds: Dataset,
                       policy, cell: CellConfig, cfg,
                       opt: Optimizer | None = None) -> Callable:
    """Participant-centric counterpart of ``engine.make_runner``.

    Returns ``runner(params, h_all, seed=None) -> SimResult`` with the same
    result contract as the dense engine (dense ``[T, K]`` participation /
    per-round energy are reconstructed host-side from the participant trace;
    ``result.state`` carries the final global model and ``last_tx`` but no
    ``[K, D]`` client stacks — the sparse path never materializes them).
    """
    from .engine import SimResult  # deferred: engine imports us

    # a pre-built store is accepted directly — at mega-populations a
    # million-element Dataset list is not viable, and the jittable
    # partitioners emit stores natively
    store = (client_data if isinstance(client_data, DeviceDataStore)
             else from_client_datasets(client_data))
    K = store.num_clients
    if opt is None:
        # value-token the default optimizer: every runner constructing the
        # default sgd(cfg.lr) shares one cache entry (fresh closures would
        # make the id()-based token miss on every make_runner call)
        opt = sgd(cfg.lr)
        opt_token = ("default-sgd", float(cfg.lr))
    else:
        opt_token = (id(opt.init), id(opt.update))
    policy_fn = as_policy_fn(policy)
    if cfg.local_mode != "participants":
        raise ValueError(
            "the sparse path implements local_mode='participants'; "
            "continuous local training is population-shaped by definition — "
            "use the dense engine for it")
    if cfg.data_stream != "client":
        raise ValueError(
            "sparse participation samples minibatches per participant and "
            "needs the per-client stream: set SimConfig(data_stream='client')")
    if cfg.overflow not in ("spill", "error"):
        raise ValueError(f"unknown overflow policy {cfg.overflow!r} "
                         "(expected spill|error)")
    if cfg.eval_mode == "replay":
        raise ValueError(
            "the sparse path evaluates in-scan; eval_mode='replay' belongs "
            "to the resumable dense driver (repro.fl.resume)")
    data_key = data_stream_key(cfg.seed)
    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    T = cfg.rounds
    ltap = metrics_active(cfg.metrics, None, parts="ledger")
    ttap = metrics_active(cfg.metrics, cfg.guards, parts="train")
    tel = get_telemetry()
    emit_run_manifest("make_sparse_runner", cfg, extra={"num_clients": K})
    phase_a: dict = {}
    gather = jax.jit(lambda pidx: gather_participant_rounds(
        store, data_key, pidx, cfg.local_iters, cfg.batch_size))

    def _phase_a(bucket: int, h_rounds, key):
        if bucket not in phase_a:
            tel.inc("sparse.phase_a_cache_miss")
            phase_a[bucket] = jax.jit(build_participation_program(
                policy_fn, cfg, cell, K, bucket))
        else:
            tel.inc("sparse.phase_a_cache_hit")
        with tel.span("sparse.phase_a"):
            return phase_a[bucket](h_rounds, key)

    def runner(params, h_all, seed: int | None = None) -> SimResult:
        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        h_rounds = jnp.swapaxes(h_all, 0, 1)
        bucket = cfg.participant_bucket or _auto_bucket(policy_fn, h_rounds,
                                                        cfg, K)
        pa = _phase_a(bucket, h_rounds, key)
        last_tx, energy, ptr = pa[0], pa[1], pa[2]
        ms_a = pa[3] if ltap else None
        n_tx = np.asarray(ptr.n_tx)
        if (n_tx > bucket).any():
            if cfg.overflow == "error":
                raise RuntimeError(
                    f"participant bucket overflow: round "
                    f"{int(n_tx.argmax())} realized {int(n_tx.max())} "
                    f"transmitters > bucket {bucket} — pass "
                    "SimConfig(participant_bucket=...) with more headroom")
            # spill fallback: regrow toward the dense width (next power of
            # two ≥ the realized max, capped at K) and rerun phase A —
            # decision math is bucket-independent, so the rerun is exact
            grown = max(bucket, 1)
            while grown < int(n_tx.max()):
                grown *= 2
            grown = min(grown, K)
            _warn_spill_once(bucket, grown, int(n_tx.max()))
            bucket = grown
            pa = _phase_a(bucket, h_rounds, key)
            last_tx, energy, ptr = pa[0], pa[1], pa[2]
            ms_a = pa[3] if ltap else None
        xb_all, yb_all = gather(ptr.part_idx)
        train = _cached_train_program(
            _train_cache_key(cfg, opt_token, loss_fn, acc_fn, params,
                             store.x.shape[2:], test_x.shape, bucket),
            lambda: build_sparse_train_program(loss_fn, acc_fn, opt, cfg))
        with tel.span("sparse.train"):
            out = train(
                params, xb_all, yb_all, ptr.valid, ptr.anchor_slot,
                jnp.int32(K), test_x, test_y, ptr.delivered, ptr.corrupt,
                ptr.stale, ptr.prob)
        g_final, (accs, losses, dids) = out[0], out[1]
        ms_b = out[2] if ttap else None

        # host-side densification of the participant trace (numpy, O(T·K))
        idx = np.asarray(ptr.part_idx)
        val = np.asarray(ptr.valid)
        e_p = np.asarray(ptr.e_p)
        t_of = np.broadcast_to(np.arange(T)[:, None], idx.shape)
        parts = np.zeros((T, K), np.float32)
        e_round = np.zeros((T, K), np.float32)
        parts[t_of[val], idx[val]] = 1.0
        e_round[t_of[val], idx[val]] = e_p[val]
        did = np.asarray(dids)
        ev = np.where(did)[0]
        state = FLState(global_params=g_final, client_params=None,
                        anchor_params=None, round=jnp.int32(T),
                        last_tx=last_tx)
        if cfg.faults is not None:
            dlv = np.asarray(ptr.delivered)
            cor = np.asarray(ptr.corrupt)
            delivered = np.zeros((T, K), np.float32)
            corrupted = np.zeros((T, K), np.float32)
            delivered[t_of[val], idx[val]] = dlv[val].astype(np.float32)
            corrupted[t_of[val], idx[val]] = cor[val].astype(np.float32)
        else:
            delivered = corrupted = None
        ms = merge_metrics(ms_a, ms_b)
        return SimResult(
            test_acc=np.asarray(accs)[ev],
            test_loss=np.asarray(losses)[ev],
            eval_rounds=ev,
            energy_per_client=np.asarray(energy),
            energy_timeline=np.cumsum(e_round.sum(axis=1)),
            participation=parts,
            state=state,
            delivered=delivered,
            corrupted=corrupted,
            metrics=(jax.tree_util.tree_map(np.asarray, ms)
                     if ms is not None else None),
        )

    runner.store = store
    return runner


def resolve_participation(cfg, policy_fn, data_path: str,
                          num_clients: int) -> str:
    """Resolve ``cfg.participation`` to ``"dense"`` or ``"sparse"``.

    ``"auto"`` picks sparse exactly when its preconditions hold — the
    participants-only local mode, a state_free or ledger policy (see
    :func:`repro.core.selection.policy_ledger_ok`), the device data path,
    and the per-client minibatch stream; anything else keeps the dense scan.
    ``"sparse"`` raises on unmet preconditions instead of silently changing
    semantics.
    """
    from ..core.selection import policy_ledger_ok

    mode = cfg.participation
    if mode not in ("dense", "sparse", "auto"):
        raise ValueError(f"unknown participation {mode!r} "
                         "(expected dense|sparse|auto)")
    ok = (cfg.local_mode == "participants" and policy_ledger_ok(policy_fn)
          and data_path == "device" and cfg.data_stream == "client")
    if mode == "auto":
        return "sparse" if ok else "dense"
    if mode == "sparse" and data_path != "device":
        raise ValueError("sparse participation gathers from the device "
                         f"store; data path {data_path!r} is not supported")
    return mode
