"""Fault-injection processes + defensive-aggregation configuration.

The paper's whole premise is that clients are unreliable — stragglers,
arbitrary transmission times, energy-limited uplinks — yet a clean simulator
tests none of it: every selected client trains, every uplink lands, every
update is finite.  This module models the faults *inside* the jitted scan so
the convergence-vs-energy claims can be stress-tested under realistic failure
regimes (FLGo-style system simulation; staleness-aware aggregation à la
Hu–Chen–Larsson arXiv:2212.07356 / FedAsync):

* **Markov on–off availability** — each client carries a two-state chain in
  the scan carry (``FaultState.avail``); an unavailable client never starts
  its upload (no transmission, no energy).
* **Diurnal availability rate** — the failure probability is modulated by a
  sinusoid of the round index with a per-client phase (staggered
  "timezones"), so scenario lanes see time-varying populations.
* **Mid-round crash/dropout** — a client that passed the Bernoulli draw
  crashes before completing its upload: nothing lands, no uplink energy is
  spent (the dropout happened before transmission).
* **Uplink loss with bounded retry-and-backoff** — each transmission attempt
  is lost with probability ``p_loss``; the client retries up to
  ``max_retries`` extra times, each attempt costing ``backoff^i`` times the
  base eq.-5 energy.  Retries consume extra energy, and a fully-lost upload
  leaves ``last_tx`` untouched — staleness grows — mirroring the paper's
  energy/bandwidth trade-off.
* **Adversarial update corruption** — a delivered update is poisoned with
  probability ``p_corrupt``: NaN / Inf injection or a scaled-norm attack
  (``corrupt_scale`` × the honest update).

Every process is a pure ``(t, key, state) -> (outcome, state)`` function of
*traced* parameters (:class:`FaultParams`), so scenario lanes can ``vmap``
over heterogeneous failure worlds (:func:`run_fault_matrix` sweeps a severity
axis in one device program) and every process composes with every selection
policy in :mod:`repro.core.selection` — faults act on the realized mask,
*after* the policy, never inside it.

The PRNG discipline matters for parity: fault draws consume dedicated
``fold_in(fold_in(base_key, t), _FAULT_SALT + i)`` streams, so enabling
faults never perturbs the participation draws, and ``faults=None`` leaves
the engine's program byte-for-byte unchanged (the existing dense/sparse/
legacy bit-parity tests keep passing untouched).

Server-side defenses are configured here too (:class:`GuardConfig`) and
implemented mask-based in :func:`repro.fl.state.guarded_aggregate`:
non-finite quarantine (reject-and-reweight instead of poisoning the global
model), update-norm clipping, and staleness-gated down-weighting.

See ``docs/robustness.md`` for the catalog, guard semantics, and the resume
protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

#: fold_in salts for the per-round fault streams — disjoint from the
#: participation draw (fold_in(base_key, t) itself) and the data streams.
_FAULT_SALT = 0x5AFE


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static fault-process configuration (frozen ⇒ usable inside jitted
    closures).  All probabilities are per round; everything defaults to the
    clean world, so ``FaultConfig()`` with one field set isolates one
    process."""

    # Markov on–off availability
    p_fail: float = 0.0        # P(up → down) per round
    p_recover: float = 1.0     # P(down → up) per round
    # diurnal modulation of the failure rate: p_fail·(1 + amp·sin(2πt/period
    # + 2πk/K)) — amp=0 disables; per-client phase staggers the "timezones"
    diurnal_amp: float = 0.0
    diurnal_period: int = 24
    # mid-round crash (selected, available, but dies before the upload)
    p_crash: float = 0.0
    # uplink loss + bounded retry-and-backoff
    p_loss: float = 0.0        # per-attempt loss probability
    max_retries: int = 0       # extra attempts after the first (static)
    backoff: float = 1.0       # attempt i costs backoff^i × the base energy
    # adversarial update corruption
    p_corrupt: float = 0.0
    corrupt_mode: str = "nan"  # "nan" | "inf" | "scale" (static)
    corrupt_scale: float = 100.0

    @classmethod
    def from_trace(cls, avail, attempts=None, delivered=None,
                   max_retries: int = 0, **overrides) -> "FaultConfig":
        """Config-level convenience over :meth:`FaultParams.from_trace`:
        fit the Markov/loss rates from a trace and return a ready-to-use
        ``FaultConfig`` (so ``SimConfig(faults=FaultConfig.from_trace(...))``
        replays the fitted failure world).  ``max_retries`` and any other
        static field ride through ``overrides``."""
        fp = FaultParams.from_trace(avail, attempts=attempts,
                                    delivered=delivered)
        return cls(p_fail=float(fp.p_fail), p_recover=float(fp.p_recover),
                   p_loss=float(fp.p_loss), max_retries=max_retries,
                   **overrides)

    def params(self) -> "FaultParams":
        """The traced-parameter view (everything a vmap axis may sweep)."""
        return FaultParams(
            p_fail=jnp.float32(self.p_fail),
            p_recover=jnp.float32(self.p_recover),
            diurnal_amp=jnp.float32(self.diurnal_amp),
            p_crash=jnp.float32(self.p_crash),
            p_loss=jnp.float32(self.p_loss),
            backoff=jnp.float32(self.backoff),
            p_corrupt=jnp.float32(self.p_corrupt),
            corrupt_scale=jnp.float32(self.corrupt_scale),
        )


class FaultParams(NamedTuple):
    """Traced counterparts of the probabilistic :class:`FaultConfig` fields.

    A pytree of f32 scalars: stack several along a leading axis and ``vmap``
    the simulation over it to sweep failure severities in one device program
    (``max_retries``/``corrupt_mode``/``diurnal_period`` stay static — they
    shape the program, not the math).
    """

    p_fail: jax.Array
    p_recover: jax.Array
    diurnal_amp: jax.Array
    p_crash: jax.Array
    p_loss: jax.Array
    backoff: jax.Array
    p_corrupt: jax.Array
    corrupt_scale: jax.Array

    @classmethod
    def from_trace(cls, avail, attempts=None, delivered=None) -> "FaultParams":
        """Fit the probabilistic fields from an observed trace (MLE).

        ``avail [T, K]`` is an availability history (bool/int — e.g. the
        :class:`FaultOutcome` ``avail`` lane stacked over rounds, or a real
        deployment's presence log): the Markov rates are transition
        frequencies, ``p_fail = #(up→down) / #(up)`` and ``p_recover =
        #(down→up) / #(down)`` over consecutive round pairs.  With no
        observed up (resp. down) dwell the clean-world defaults ``0.0`` /
        ``1.0`` stand.

        ``attempts``/``delivered`` (``[T, K]``, optional, together) fit the
        uplink loss: every delivered upload ends in exactly one success, so
        ``p_loss = (Σ attempts − #delivered) / Σ attempts``.

        Everything unobservable from these traces (diurnal modulation,
        crash/corruption rates, backoff) keeps its clean default — fit what
        the trace pins down, assume nothing else.
        """
        a = np.asarray(avail).astype(bool)
        if a.ndim != 2:
            raise ValueError(f"avail must be [T, K], got shape {a.shape}")
        prev, nxt = a[:-1], a[1:]
        n_up = int(prev.sum())
        n_down = int(prev.size - n_up)
        p_fail = float((prev & ~nxt).sum() / n_up) if n_up else 0.0
        p_recover = float((~prev & nxt).sum() / n_down) if n_down else 1.0
        p_loss = 0.0
        if (attempts is None) != (delivered is None):
            raise ValueError("attempts and delivered must be given together")
        if attempts is not None:
            att = np.asarray(attempts, np.float64)
            dlv = np.asarray(delivered).astype(bool)
            if att.shape != dlv.shape:
                raise ValueError("attempts and delivered shapes differ: "
                                 f"{att.shape} vs {dlv.shape}")
            total = float(att.sum())
            if total > 0:
                p_loss = float(np.clip((total - dlv.sum()) / total, 0.0, 1.0))
        return FaultConfig(p_fail=p_fail, p_recover=p_recover,
                           p_loss=p_loss).params()


def scale_params(fp: FaultParams, rate) -> FaultParams:
    """Scale every *failure* probability by ``rate`` (clipped to [0, 1]) —
    the severity axis of a degradation sweep.  Recovery, backoff and the
    corruption magnitude are left alone: ``rate=0`` is the clean world,
    ``rate=1`` the configured one."""
    r = jnp.asarray(rate, jnp.float32)
    clip = lambda p: jnp.clip(p * r, 0.0, 1.0)  # noqa: E731
    return fp._replace(p_fail=clip(fp.p_fail), p_crash=clip(fp.p_crash),
                       p_loss=clip(fp.p_loss), p_corrupt=clip(fp.p_corrupt))


class FaultState(NamedTuple):
    """Per-client fault state carried in the scan."""

    avail: jax.Array   # [K] bool — Markov on–off chain state (True = up)


class FaultOutcome(NamedTuple):
    """Per-round fault realization (all ``[K]``)."""

    delivered: jax.Array   # f32 — update actually landed at the server
    corrupt: jax.Array     # bool — delivered but adversarially corrupted
    attempts: jax.Array    # f32 — uplink attempts made (0 = never started)
    avail: jax.Array       # bool — availability after this round's step
    e_round: jax.Array     # f32 — energy including retry overhead


def init_fault_state(num_clients: int) -> FaultState:
    """Everyone starts available (the chain mixes within a few rounds)."""
    return FaultState(avail=jnp.ones((num_clients,), bool))


def fault_key(base_key: jax.Array, t: jax.Array, i: int) -> jax.Array:
    """Stream i of round t — disjoint from the participation draw by salt."""
    return jax.random.fold_in(jax.random.fold_in(base_key, t),
                              _FAULT_SALT + i)


# ---------------------------------------------------------------------------
# the individual processes — pure (t, key, state) -> (outcome, state)
# ---------------------------------------------------------------------------


def markov_availability(t, key, avail, fp: FaultParams,
                        cfg: FaultConfig):
    """One step of the per-client on–off chain with diurnal modulation.

    Returns ``(avail', avail')`` — the outcome *is* the new state.  The
    failure rate is ``p_fail·(1 + amp·sin(2πt/period + φ_k))`` clipped to
    [0, 1], with per-client phase ``φ_k = 2πk/K``.
    """
    K = avail.shape[0]
    phase = 2.0 * jnp.pi * jnp.arange(K, dtype=jnp.float32) / K
    tt = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    mod = 1.0 + fp.diurnal_amp * jnp.sin(
        2.0 * jnp.pi * tt / cfg.diurnal_period + phase)
    p_fail_t = jnp.clip(fp.p_fail * mod, 0.0, 1.0)
    u = jax.random.uniform(key, (K,))
    new_avail = jnp.where(avail, u >= p_fail_t, u < fp.p_recover)
    return new_avail, new_avail


def crash_process(t, key, mask, fp: FaultParams):
    """Mid-round crash: a selected client dies before its upload starts.
    Returns ``(crashed [K] bool, None)`` — memoryless, no carried state."""
    del t
    u = jax.random.uniform(key, mask.shape)
    return (mask > 0) & (u < fp.p_crash), None


def uplink_process(t, key, mask, fp: FaultParams, cfg: FaultConfig):
    """Lossy uplink with bounded retry-and-backoff.

    Each attempt i ∈ {0..max_retries} is independently lost with probability
    ``p_loss``; the client stops at its first success.  Returns
    ``(landed [K] bool, attempts [K] f32, energy_mult [K] f32, None)`` where
    ``energy_mult = Σ_{i<attempts} backoff^i`` multiplies the base eq.-5
    round energy — retries are paid for whether or not the update ever lands.
    """
    del t
    K = mask.shape[0]
    A = cfg.max_retries + 1
    u = jax.random.uniform(key, (A, K))
    ok = u >= fp.p_loss                               # [A, K] attempt success
    # first success index; A if every attempt lost
    first = jnp.argmax(ok, axis=0)
    any_ok = jnp.any(ok, axis=0)
    attempts = jnp.where(any_ok, first + 1, A).astype(jnp.float32)
    # Σ_{i<a} backoff^i, branch-free over the static attempt budget
    i = jnp.arange(A, dtype=jnp.float32)[:, None]
    cost = jnp.where(i < attempts[None, :], fp.backoff ** i, 0.0)
    return any_ok, attempts, jnp.sum(cost, axis=0), None


def corruption_process(t, key, delivered, fp: FaultParams):
    """Adversarial corruption draw over *delivered* updates.  Returns
    ``(corrupt [K] bool, None)``; the transform itself is
    :func:`corrupt_deltas` (applied where the deltas live — dense round step
    or sparse phase B)."""
    del t
    u = jax.random.uniform(key, delivered.shape)
    return (delivered > 0) & (u < fp.p_corrupt), None


def corrupt_deltas(deltas: Any, corrupt: jax.Array, fp: FaultParams,
                   cfg: FaultConfig) -> Any:
    """Apply the configured corruption to the flagged rows of a stacked
    delta pytree (leading axis = clients or participants).

    ``"nan"``/``"inf"`` poison every element of the flagged update;
    ``"scale"`` is the scaled-norm attack (``corrupt_scale × δ`` — finite,
    so it slips past a pure finiteness quarantine and exercises norm
    clipping)."""
    if cfg.corrupt_mode == "scale":
        bad = lambda d: d * fp.corrupt_scale  # noqa: E731
    elif cfg.corrupt_mode == "nan":
        bad = lambda d: jnp.full_like(d, jnp.nan)  # noqa: E731
    elif cfg.corrupt_mode == "inf":
        bad = lambda d: jnp.full_like(d, jnp.inf)  # noqa: E731
    else:
        raise ValueError(f"unknown corrupt_mode {cfg.corrupt_mode!r} "
                         "(expected nan|inf|scale)")

    def one(d):
        c = corrupt.reshape((-1,) + (1,) * (d.ndim - 1))
        return jnp.where(c, bad(d), d)

    return jax.tree_util.tree_map(one, deltas)


# ---------------------------------------------------------------------------
# the composed per-round pipeline (what the engines call)
# ---------------------------------------------------------------------------


def apply_faults(t, base_key, mask, e_round, fstate: FaultState,
                 fp: FaultParams, cfg: FaultConfig):
    """Run every configured process on one round's realized decision.

    ``mask``/``e_round`` are the *decision* outputs of
    ``apply_round_decision`` (who wanted to transmit, at what base cost);
    the pipeline decides what actually lands:

    1. availability — down clients never start (no energy),
    2. crash — dies before upload (no uplink energy),
    3. uplink loss — retries multiply the energy; total loss delivers
       nothing but still pays,
    4. corruption — flags delivered updates for poisoning.

    Returns ``(FaultOutcome, FaultState)``.  Pure, branch-free, and all
    randomness comes from salted ``fold_in`` streams of ``(base_key, t)`` —
    the legacy host loop and the scan engine realize identical faults.
    """
    avail, _ = markov_availability(t, fault_key(base_key, t, 0),
                                   fstate.avail, fp, cfg)
    started = mask * avail.astype(mask.dtype)
    crashed, _ = crash_process(t, fault_key(base_key, t, 1), started, fp)
    uploading = started * (~crashed).astype(mask.dtype)
    landed, attempts, e_mult, _ = uplink_process(
        t, fault_key(base_key, t, 2), uploading, fp, cfg)
    delivered = uploading * landed.astype(mask.dtype)
    # energy: only clients that reached the uplink pay, scaled by retries
    e_round = e_round * uploading * e_mult
    attempts = attempts * uploading
    corrupt, _ = corruption_process(t, fault_key(base_key, t, 3),
                                    delivered, fp)
    return (FaultOutcome(delivered=delivered, corrupt=corrupt,
                         attempts=attempts, avail=avail, e_round=e_round),
            FaultState(avail=avail))


# ---------------------------------------------------------------------------
# defensive aggregation configuration (array code: repro.fl.state)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Server-side aggregation defenses — all mask-based, so the disabled
    configuration is bit-identical to the unguarded path.

    * ``quarantine`` — reject updates containing NaN/Inf (the whole client
      row) instead of letting one poisoned upload wipe the global model;
      the surviving set keeps the paper's 1/K averaging (reject-and-reweight:
      rejected mass is simply not added).
    * ``clip_norm`` — per-client L2 clip of the pseudo-gradient: δ is scaled
      by ``min(1, clip_norm/‖δ‖)``, which bounds the scaled-norm attack.
    * ``staleness_power`` — FedAsync-style polynomial down-weighting
      ``(1 + Δτ)^{-power}`` of stale updates (Δτ = rounds since the
      client's last delivered transmission).
    * ``staleness_cap`` — hard gate: updates staler than the cap are dropped
      outright (weight 0).
    """

    quarantine: bool = True
    clip_norm: Optional[float] = None
    staleness_power: float = 0.0
    staleness_cap: Optional[int] = None

    @property
    def active(self) -> bool:
        return (self.quarantine or self.clip_norm is not None
                or self.staleness_power != 0.0
                or self.staleness_cap is not None)


class FaultMatrixResult(NamedTuple):
    """Degradation sweep output (:func:`run_fault_matrix`): leading axis =
    the severity rates, one entry per guard setting."""

    rates: np.ndarray            # [R] severity multipliers
    acc: dict                    # {"guarded"/"unguarded": [R, n_evals]}
    loss: dict                   # same shape
    eval_rounds: np.ndarray      # [n_evals]
    energy: dict                 # {...: [R, K]} cumulative Joules
    delivered: dict              # {...: [R, T, K]} realized deliveries
    finite_final: dict           # {...: [R] bool} final params all finite
    # {...: MetricsState with [R]-leading leaves} when cfg.metrics enables
    # taps; None otherwise.
    metrics: Any = None


def run_fault_matrix(init_params, loss_fn, acc_fn, client_data, test_ds,
                     policy, h_all: jax.Array, cell, cfg,
                     rates: Sequence[float],
                     guard: Optional[GuardConfig] = None) -> FaultMatrixResult:
    """One sweep → a degradation curve: accuracy/energy vs fault severity,
    guarded vs unguarded, in one vmapped device program per guard setting.

    ``cfg.faults`` must be set; each lane runs the identical simulation with
    every failure probability scaled by its rate (:func:`scale_params` — rate
    0 is the clean world).  The guarded setting uses ``guard`` (default: the
    all-on :class:`GuardConfig`); the unguarded one runs ``guards=None``.
    """
    import dataclasses as _dc

    from ..data.device import data_stream_key, from_client_datasets
    from ..optim import sgd
    from .engine import build_scan_sim, resolve_data_path

    if cfg.faults is None:
        raise ValueError("run_fault_matrix needs SimConfig(faults=...)")
    guard = guard or GuardConfig(quarantine=True, clip_norm=10.0,
                                 staleness_power=0.5)
    K = h_all.shape[0]
    opt = sgd(cfg.lr)
    from ..core.selection import as_policy_fn
    policy_fn = as_policy_fn(policy)
    test_x = test_ds.x[: cfg.eval_batch]
    test_y = test_ds.y[: cfg.eval_batch]
    h_rounds = jnp.swapaxes(h_all, 0, 1)
    key = jax.random.PRNGKey(cfg.seed)
    path = resolve_data_path(client_data, cfg)
    if path == "prestack":
        from .engine import stack_round_batches
        data = stack_round_batches(client_data, cfg)
    else:  # stream resolves to the device store under vmap fan-out
        data = (from_client_datasets(client_data), data_stream_key(cfg.seed))
    base_fp = cfg.faults.params()
    rates_arr = jnp.asarray(list(rates), jnp.float32)
    fp_stack = jax.vmap(lambda r: scale_params(base_fp, r))(rates_arr)

    from ..obs.taps import metrics_active
    from ..obs.telemetry import emit_run_manifest, get_telemetry
    emit_run_manifest("run_fault_matrix", cfg,
                      extra={"rates": len(rates), "num_clients": int(K)})

    out_acc, out_loss, out_energy, out_del, out_fin = {}, {}, {}, {}, {}
    out_ms: dict = {}
    eval_rounds = None
    for name, guards in (("unguarded", None), ("guarded", guard)):
        cfg_g = _dc.replace(cfg, guards=guards)
        tapped = metrics_active(cfg_g.metrics, cfg_g.guards)
        sim = build_scan_sim(loss_fn, acc_fn, opt, cfg_g, cell, K, policy_fn,
                             shard_clients=False,
                             data_mode=("prestack" if path == "prestack"
                                        else "device"))
        fan = jax.jit(jax.vmap(
            lambda fp: sim(init_params, data[0], data[1], h_rounds, key,
                           test_x, test_y, fault_params=fp)))
        with get_telemetry().span("fault_matrix.execute"):
            out = fan(fp_stack)
        state, energy, traces = out[0], out[1], out[2]
        if tapped:
            out_ms[name] = jax.tree_util.tree_map(np.asarray, out[3])
        did = np.asarray(traces.did_eval)
        idx = np.where(did.reshape(-1, did.shape[-1])[0])[0]
        eval_rounds = idx
        out_acc[name] = np.asarray(traces.acc)[..., idx]
        out_loss[name] = np.asarray(traces.loss)[..., idx]
        out_energy[name] = np.asarray(energy)
        out_del[name] = np.asarray(traces.delivered)
        fin = jnp.stack([
            jnp.all(jnp.stack([jnp.all(jnp.isfinite(l[r]))
                               for l in jax.tree_util.tree_leaves(
                                   state.global_params)]))
            for r in range(len(rates_arr))])
        out_fin[name] = np.asarray(fin)

    return FaultMatrixResult(rates=np.asarray(rates_arr), acc=out_acc,
                             loss=out_loss, eval_rounds=eval_rounds,
                             energy=out_energy, delivered=out_del,
                             finite_final=out_fin,
                             metrics=out_ms or None)
