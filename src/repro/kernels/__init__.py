"""Pallas TPU kernels for the perf-critical hot-spots.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling) with its jnp oracle in ref.py and the jit'd dispatch wrapper in
ops.py.  Validated in interpret mode on CPU; TPU is the target.
"""
from . import ops, ref
from .fl_aggregate import fl_aggregate
from .flash_attention import flash_attention
from .selective_scan import selective_scan

__all__ = ["ops", "ref", "fl_aggregate", "flash_attention", "selective_scan"]
