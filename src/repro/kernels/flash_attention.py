"""Pallas TPU kernel: causal GQA flash attention (forward).

Streaming-softmax over KV blocks with VMEM scratch accumulators — the
standard TPU flash schedule:

  grid = (B, H, S/BQ, S/BK)   last axis sequential (reduction)
  q block   (BQ, hd)   — revisited across the KV axis
  k/v block (BK, hd)   — marched along the last grid axis
  scratch   m/l (BQ, 128) fp32, acc (BQ, hd) fp32  (VMEM)

BQ = BK = 128 aligns the MXU (128×128 systolic array).  GQA maps query head
h → kv head h // G in the BlockSpec index_map, so KV is never duplicated in
HBM.  Causal masking is index arithmetic inside the kernel; fully-masked
blocks contribute nothing (NEG_INF scores wash out of the running softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bq: int, bk: int, causal: bool,
            window: int | None):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [BQ, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [BK, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [BQ, BK]

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # [BQ]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[:, 0] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] → [B,S,H,hd].  S % bq == S % bk == 0."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / (hd ** 0.5)

    qt = q.transpose(0, 2, 1, 3)   # [B,H,S,hd]
    kt = k.transpose(0, 2, 1, 3)   # [B,KV,S,hd]
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, S // bq, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, causal=causal,
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max m
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
