"""Pallas TPU kernel: masked pseudo-gradient aggregation (paper eq. 3).

The server update ``x ← x + (1/K) Σ_{k∈C_t} δ_k`` is a pure HBM-bandwidth
op over K × P bytes every round.  Fusing mask·scale·reduce·add into one pass
reads each δ tile once and writes the updated global tile once — ~2× less
HBM traffic than the unfused jnp chain (mask-mul materializes a K×P temp).

Grid: one step per (rows/BLOCK_R) tile.  Block shapes:
  deltas  (K, BLOCK_R, 128)  — client axis reduced in VMEM
  global  (BLOCK_R, 128)
  mask    (K, 1)             — broadcast to every grid step
VMEM per step (K=16, BLOCK_R=64, fp32): 16·64·128·4 ≈ 512 KB. MXU-free
(VPU reduction), 128-lane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 64
LANE = 128


def _kernel(mask_ref, global_ref, deltas_ref, out_ref, *, inv_k: float,
            guard: bool):
    d = deltas_ref[...].astype(jnp.float32)          # [K, BR, 128]
    if guard:
        # non-finite quarantine, fused: a rejected row arrives with mask 0,
        # but 0 · NaN = NaN — zero the poison in VMEM so the zero weight
        # actually rejects it.  One extra VPU pass over data already
        # resident; no sanitized [K, M] copy ever exists in HBM.
        d = jnp.where(jnp.isfinite(d), d, 0.0)
    m = mask_ref[...].astype(jnp.float32)            # [K, 1]
    agg = jnp.sum(d * m[:, :, None], axis=0) * inv_k  # [BR, 128]
    out_ref[...] = (global_ref[...].astype(jnp.float32)
                    + agg).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "denom", "guard"))
def fl_aggregate(global_p: jax.Array, deltas: jax.Array, mask: jax.Array,
                 interpret: bool = True,
                 denom: int | None = None,
                 guard: bool = False) -> jax.Array:
    """global_p: [M]; deltas: [R, M]; mask: [R] → updated global [M].

    ``R`` is the *row* count of the delta block — the full population K in
    the dense path, or a padded participant bucket P in the sparse path.
    ``denom`` is the eq.-3 averaging denominator (the population size K);
    it defaults to ``R``, which is only correct when the rows ARE the whole
    population.  The sparse path passes ``deltas: [P, M]`` for the gathered
    transmitting set with ``mask`` = its validity lanes and ``denom=K``, so
    one compiled kernel shape serves every population size sharing a bucket.

    ``guard=True`` zeroes non-finite delta elements inside the kernel
    (defensive aggregation: a quarantined row carries mask 0, and in-VMEM
    sanitization keeps its NaN/Inf from poisoning the reduction).  The
    default ``False`` path is byte-identical to the pre-guard kernel.

    M is padded to a (BLOCK_R·128) multiple internally.
    """
    R, M = deltas.shape
    inv_k = 1.0 / (R if denom is None else int(denom))
    tile = BLOCK_R * LANE
    Mp = (M + tile - 1) // tile * tile
    gp = jnp.pad(global_p, (0, Mp - M)).reshape(Mp // LANE, LANE)
    dp = jnp.pad(deltas, ((0, 0), (0, Mp - M))).reshape(R, Mp // LANE, LANE)
    grid = (Mp // tile,)

    out = pl.pallas_call(
        functools.partial(_kernel, inv_k=inv_k, guard=guard),
        grid=grid,
        in_specs=[
            pl.BlockSpec((R, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((R, BLOCK_R, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp // LANE, LANE), global_p.dtype),
        interpret=interpret,
    )(mask.reshape(R, 1), gp, dp)
    return out.reshape(Mp)[:M]
