"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fl_aggregate_ref(global_p: jax.Array, deltas: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Eq. (3): out = global + (1/K) Σ_k mask_k · δ_k.

    global_p: [M]; deltas: [K, M]; mask: [K].
    """
    K = deltas.shape[0]
    agg = jnp.sum(deltas.astype(jnp.float32)
                  * mask.astype(jnp.float32)[:, None], axis=0) / K
    return (global_p.astype(jnp.float32) + agg).astype(global_p.dtype)


def fl_aggregate_subset_ref(global_p: jax.Array, deltas: jax.Array,
                            valid: jax.Array, num_clients) -> jax.Array:
    """Participant-subset eq. (3): out = global + (1/K) Σ_p valid_p · δ_p.

    global_p: [M]; deltas: [P, M] (gathered transmitting set, padded);
    valid: [P] lanes; ``num_clients`` is the population K — may be a traced
    scalar, so one compiled program serves every K sharing a bucket.
    """
    agg = jnp.sum(deltas.astype(jnp.float32)
                  * valid.astype(jnp.float32)[:, None], axis=0)
    agg = agg / jnp.asarray(num_clients, jnp.float32)
    return (global_p.astype(jnp.float32) + agg).astype(global_p.dtype)


def fl_aggregate_guarded_ref(global_p: jax.Array, deltas: jax.Array,
                             weights: jax.Array) -> jax.Array:
    """Defensively-weighted eq. (3) oracle: out = global + Σ_r w_r·δ'_r with
    δ' = δ where finite else 0.

    global_p: [M]; deltas: [R, M]; weights: [R] — the caller folds the
    participation mask, guard weights and the 1/K denominator into
    ``weights`` (matching the ``denom=1`` kernel contract).
    """
    d = deltas.astype(jnp.float32)
    d = jnp.where(jnp.isfinite(d), d, 0.0)
    agg = jnp.sum(d * weights.astype(jnp.float32)[:, None], axis=0)
    return (global_p.astype(jnp.float32) + agg).astype(global_p.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Causal (optionally sliding-window) GQA attention.

    q: [B, S, H, hd]; k, v: [B, S, KV, hd]; H % KV == 0.  fp32 softmax.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def selective_scan_ref(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                       Cm: jax.Array, A: jax.Array,
                       D: jax.Array) -> jax.Array:
    """Mamba S6 recurrence (fp32).

    xc, dt: [B, S, d]; Bm, Cm: [B, S, N]; A: [d, N]; D: [d] → y [B, S, d].
    """
    dA = jnp.exp(dt[..., None] * A)                              # [B,S,d,N]
    dBx = (dt[..., None] * Bm[..., None, :]) * xc[..., None]

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm) + D * xc
    return y
