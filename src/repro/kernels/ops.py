"""jit'd dispatch wrappers: Pallas kernel on TPU, jnp oracle elsewhere.

``use_pallas=None`` auto-selects: the kernels are TPU-targeted
(pl.pallas_call + BlockSpec VMEM tiling); on this CPU container they execute
in interpret mode (Python evaluation of the kernel body) — correct but slow,
so the model code defaults to the jnp path and the kernels are exercised by
the test sweeps + benchmarks.
"""
from __future__ import annotations

import jax

from . import ref
from .fl_aggregate import fl_aggregate as _fl_aggregate_pallas
from .flash_attention import flash_attention as _flash_pallas
from .selective_scan import selective_scan as _scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fl_aggregate(global_p, deltas, mask, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _fl_aggregate_pallas(global_p, deltas, mask,
                                    interpret=not _on_tpu())
    return ref.fl_aggregate_ref(global_p, deltas, mask)


def fl_aggregate_subset(global_p, deltas, valid, num_clients,
                        use_pallas: bool | None = None):
    """Participant-subset eq. (3): deltas [P, M] + validity lanes, averaged
    over the *population* ``num_clients`` (may be traced — it is folded into
    the mask so the Pallas kernel shape depends only on the bucket P)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        import jax.numpy as jnp
        scaled = (valid.astype(jnp.float32)
                  / jnp.asarray(num_clients, jnp.float32))
        return _fl_aggregate_pallas(global_p, deltas, scaled,
                                    interpret=not _on_tpu(), denom=1)
    return ref.fl_aggregate_subset_ref(global_p, deltas, valid, num_clients)


def fl_aggregate_guarded(global_p, deltas, weights,
                         use_pallas: bool | None = None):
    """Defensively-weighted eq. (3): ``out = global + Σ_r w_r · sanitize(δ_r)``.

    ``weights`` is the fully-folded per-row coefficient (participation mask ×
    guard weights × 1/K) — the caller owns the averaging semantics; non-finite
    delta elements are zeroed *inside* the reduction, so a quarantined row
    (weight 0) cannot poison the global model.  Pallas path fuses the
    sanitize into the VMEM pass (no [R, M] sanitized copy in HBM)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _fl_aggregate_pallas(global_p, deltas, weights,
                                    interpret=not _on_tpu(), denom=1,
                                    guard=True)
    return ref.fl_aggregate_guarded_ref(global_p, deltas, weights)


def flash_attention(q, k, v, causal=True, window=None,
                    use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def selective_scan(xc, dt, Bm, Cm, A, D, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _scan_pallas(xc, dt, Bm, Cm, A, D, interpret=not _on_tpu())
    return ref.selective_scan_ref(xc, dt, Bm, Cm, A, D)
