"""Pallas TPU kernel: Mamba S6 selective scan (forward).

The recurrence h_t = exp(Δ_t A)⊙h_{t-1} + (Δ_t B_t)x_t is sequential in t but
embarrassingly parallel over (batch, channel-block).  Schedule:

  grid = (B, d/BD, S/SC)   last axis sequential ("arbitrary")
  blocks: xc/dt (1, SC, BD); B/C (1, SC, N); A (BD, N); D (1, BD)
  scratch: h (BD, N) fp32 — the recurrent state, persistent across the S axis

The [B,S,d,N] tensor of the naive formulation is never materialized: VMEM
holds one (SC, BD) input tile and the (BD, N) state (BD=256, N=16, SC=128:
~200 KB).  The channel axis BD=256 is lane-aligned (128×2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BD = 256
DEFAULT_SC = 128


def _kernel(xc_ref, dt_ref, bm_ref, cm_ref, a_ref, d_ref, y_ref, h_ref, *,
            sc: int):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                 # [BD, N]
    Dv = d_ref[...].astype(jnp.float32)[0]             # [BD]

    def step(t, h):
        # leading axis sliced with ds(0, 1), not a bare int: the interpret
        # path's load discharge rule rejects scalar indexer components on
        # this jax version
        lead = pl.ds(0, 1)
        dt_t = pl.load(dt_ref, (lead, pl.ds(t, 1), slice(None)))[0, 0]  # [BD]
        x_t = pl.load(xc_ref, (lead, pl.ds(t, 1), slice(None)))[0, 0]
        b_t = pl.load(bm_ref, (lead, pl.ds(t, 1), slice(None)))[0, 0]   # [N]
        c_t = pl.load(cm_ref, (lead, pl.ds(t, 1), slice(None)))[0, 0]
        dt_f = dt_t.astype(jnp.float32)
        dA = jnp.exp(dt_f[:, None] * A)                # [BD, N]
        h = dA * h + (dt_f * x_t.astype(jnp.float32))[:, None] \
            * b_t.astype(jnp.float32)[None, :]
        y = jnp.sum(h * c_t.astype(jnp.float32)[None, :], axis=1) \
            + Dv * x_t.astype(jnp.float32)
        pl.store(y_ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 y.astype(y_ref.dtype)[None, None, :])
        return h

    h = jax.lax.fori_loop(0, sc, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("bd", "sc", "interpret"))
def selective_scan(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                   Cm: jax.Array, A: jax.Array, D: jax.Array, *,
                   bd: int = DEFAULT_BD, sc: int = DEFAULT_SC,
                   interpret: bool = True) -> jax.Array:
    """xc, dt: [B,S,d]; Bm, Cm: [B,S,N]; A: [d,N]; D: [d] → y [B,S,d] fp32.

    d % bd == 0 and S % sc == 0 (pad upstream if needed).
    """
    B, S, d = xc.shape
    N = Bm.shape[-1]
    bd = min(bd, d)
    sc = min(sc, S)
    assert d % bd == 0 and S % sc == 0, (d, bd, S, sc)

    grid = (B, d // bd, S // sc)
    return pl.pallas_call(
        functools.partial(_kernel, sc=sc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sc, bd), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, sc, bd), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, sc, N), lambda b, c, s: (b, s, 0)),
            pl.BlockSpec((1, sc, N), lambda b, c, s: (b, s, 0)),
            pl.BlockSpec((bd, N), lambda b, c, s: (c, 0)),
            pl.BlockSpec((1, bd), lambda b, c, s: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, sc, bd), lambda b, c, s: (b, s, c)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xc, dt, Bm, Cm, A, D.reshape(1, d))
