"""Generic decoder stack: embed → lax.scan over super-blocks → norm → logits.

A *super-block* is ``cfg.layer_plan()`` — a short list of (mixer, ffn) layer
specs; its params are stacked on a leading ``n_repeats`` axis and the stack is
a single ``lax.scan``, so compiled HLO size is independent of depth (72-layer
Jamba compiles the same graph as an 8-layer one).

Entry points (all pure):
  init_params(key, cfg)
  forward(params, cfg, tokens|embeds)              → logits          (train)
  loss(params, cfg, batch)                         → (scalar, aux)
  prefill(params, cfg, tokens|embeds, capacity)    → (logits, caches)
  decode_step(params, cfg, token, caches)          → (logits, caches)
  init_caches(cfg, batch, capacity, dtype)
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention, mamba, moe, xlstm
from .costmode import cost_mode
from .layers import dense_init, init_swiglu, rms_norm, swiglu


def _dtype(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, mixer: str, ffn: str, dtype):
    kmix, kffn = jax.random.split(key)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if mixer == "attn":
        p["mixer"] = attention.init_attn(kmix, cfg, dtype)
    elif mixer == "mamba":
        p["mixer"] = mamba.init_mamba(kmix, cfg, dtype)
    elif mixer == "mlstm":
        p["mixer"] = xlstm.init_mlstm(kmix, cfg, dtype)
    elif mixer == "slstm":
        p["mixer"] = xlstm.init_slstm(kmix, cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if ffn == "dense":
        p["ffn"] = init_swiglu(kffn, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["ffn"] = moe.init_moe(kffn, cfg, dtype)
    return p


def init_params(key, cfg: ArchConfig):
    dtype = _dtype(cfg)
    plan = cfg.layer_plan()
    keys = jax.random.split(key, cfg.n_repeats * len(plan) + 3)

    # stacked super-block params: leaf shape [n_repeats, ...]
    blocks = []
    ki = 0
    for r in range(cfg.n_repeats):
        sb = []
        for (mixer, ffn) in plan:
            sb.append(_init_layer(keys[ki], cfg, mixer, ffn, dtype))
            ki += 1
        blocks.append(sb)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)

    params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "blocks": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[-2], cfg.d_model, cfg.vocab, dtype)
    return params


# ---------------------------------------------------------------------------
# super-block application
# ---------------------------------------------------------------------------

def _apply_layer(lp, cfg: ArchConfig, mixer: str, ffn: str, x, positions,
                 mode: str, cache, capacity: int):
    """One layer.  Returns (x, new_cache, aux)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    new_cache = cache
    if mixer == "attn":
        if mode == "train":
            y = attention.attn_forward(lp["mixer"], cfg, h, positions)
        elif mode == "prefill":
            y, new_cache = attention.attn_prefill(lp["mixer"], cfg, h,
                                                  positions, capacity)
        else:
            y, new_cache = attention.attn_decode(lp["mixer"], cfg, h, cache)
    elif mixer == "mamba":
        if mode == "train":
            y = mamba.mamba_forward(lp["mixer"], cfg, h)
        elif mode == "prefill":
            y, new_cache = mamba.mamba_forward(lp["mixer"], cfg, h,
                                               return_cache=True)
        else:
            y, new_cache = mamba.mamba_decode(lp["mixer"], cfg, h, cache)
    elif mixer == "mlstm":
        if mode == "train":
            y = xlstm.mlstm_forward(lp["mixer"], cfg, h)
        elif mode == "prefill":
            y, new_cache = xlstm.mlstm_forward(lp["mixer"], cfg, h,
                                               return_cache=True)
        else:
            y, new_cache = xlstm.mlstm_decode(lp["mixer"], cfg, h, cache)
    elif mixer == "slstm":
        if mode == "train":
            y = xlstm.slstm_forward(lp["mixer"], cfg, h)
        elif mode == "prefill":
            y, new_cache = xlstm.slstm_forward(lp["mixer"], cfg, h,
                                               return_cache=True)
        else:
            y, new_cache = xlstm.slstm_decode(lp["mixer"], cfg, h, cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w1"], lp["ffn"]["w3"], lp["ffn"]["w2"])
    elif ffn == "moe":
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = moe.moe_forward(lp["ffn"], cfg, h)
        x = x + y
    return x, new_cache, aux


def _layer_cache(cfg: ArchConfig, mixer: str, batch: int, capacity: int,
                 dtype):
    if mixer == "attn":
        cap = min(capacity, cfg.sliding_window) if cfg.sliding_window \
            else capacity
        return attention.init_cache(cfg, batch, cap, dtype)
    if mixer == "mamba":
        return mamba.init_mamba_cache(cfg, batch, dtype)
    if mixer == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch, dtype)
    if mixer == "slstm":
        return xlstm.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(mixer)


def init_caches(cfg: ArchConfig, batch: int, capacity: int, dtype=None):
    """Stacked caches: pytree with leading [n_repeats] axis per plan position."""
    dtype = dtype or _dtype(cfg)
    plan = cfg.layer_plan()
    per_pos = [_layer_cache(cfg, m, batch, capacity, dtype) for m, _ in plan]
    return jax.tree_util.tree_map(
        lambda c: jnp.broadcast_to(c[None], (cfg.n_repeats,) + c.shape),
        tuple(per_pos))


def _scan_blocks(params, cfg: ArchConfig, x, positions, mode: str,
                 caches, capacity: int):
    """Scan over the stacked super-blocks.

    ``params["blocks"]`` is a list (per super-block position) of layer-param
    dicts whose leaves carry a leading [n_repeats] axis; ``caches`` (optional)
    is a tuple with the same leading axis.  Returns (x, aux, new_caches).
    """
    plan = cfg.layer_plan()
    aux0 = jnp.zeros((), jnp.float32)

    if cost_mode():
        # unrolled python loop — exact HLO op counts for the cost probes
        aux = aux0
        new_caches = []
        for r in range(cfg.n_repeats):
            bp = jax.tree_util.tree_map(lambda l: l[r], params["blocks"])
            ncs = []
            for i, (mixer, ffn) in enumerate(plan):
                c_i = None if caches is None else \
                    jax.tree_util.tree_map(lambda l: l[r], caches[i])
                x, nc, a = _apply_layer(bp[i], cfg, mixer, ffn, x, positions,
                                        mode, c_i, capacity)
                ncs.append(nc)
                aux = aux + a
            new_caches.append(tuple(ncs))
        if caches is None:
            return x, aux, None
        stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                         *new_caches)
        return x, aux, stacked

    if caches is None:
        from .pshard import shard_dim

        def body(carry, bp):
            x, aux = carry
            # sequence parallelism at super-block boundaries (§Perf iter 7):
            # the scan saves its carry for backward — sharding the S dim
            # over "model" cuts the 48×[B,S,d] residual saves 16×; GSPMD
            # all-gathers/reduce-scatters around each block as needed.
            x = shard_dim(x, -2, "model")
            for i, (mixer, ffn) in enumerate(plan):
                x, _, a = _apply_layer(bp[i], cfg, mixer, ffn, x, positions,
                                       mode, None, capacity)
                aux = aux + a
            return (x, aux), None

        if mode == "train":
            # activation checkpointing per super-block: backward recomputes
            # the block instead of keeping every intermediate of the scan
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
        return x, aux, None

    def body(carry, scanned):
        x, aux = carry
        bp, cache = scanned
        new_caches = []
        for i, (mixer, ffn) in enumerate(plan):
            x, nc, a = _apply_layer(bp[i], cfg, mixer, ffn, x, positions,
                                    mode, cache[i], capacity)
            new_caches.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_caches)

    (x, aux), new_caches = jax.lax.scan(body, (x, aux0),
                                        (params["blocks"], caches))
    return x, aux, new_caches


# ---------------------------------------------------------------------------
# model-level API
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens=None, embeds=None):
    if embeds is not None:
        return embeds.astype(_dtype(cfg))
    return jnp.take(params["embed"], tokens, axis=0)


def _logits(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return (x @ params["embed"].T).astype(jnp.float32)
    return (x @ params["unembed"]).astype(jnp.float32)


def forward_hidden(params, cfg: ArchConfig, tokens=None, embeds=None):
    """Full-sequence causal forward → (hidden [B,S,d], aux)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, aux, _ = _scan_blocks(params, cfg, x, positions, "train", None, 0)
    return x, aux


def forward(params, cfg: ArchConfig, tokens=None, embeds=None):
    """Full-sequence causal forward → (logits [B,S,V] fp32, aux)."""
    x, aux = forward_hidden(params, cfg, tokens, embeds)
    return _logits(params, cfg, x), aux


def loss(params, cfg: ArchConfig, batch):
    """Next-token (or labeled) cross-entropy + MoE aux loss.

    batch: {"tokens": [B,S]} or {"embeds": [B,S,d], "labels": [B,S]}.

    Vocab-parallel CE (§Perf iteration 5): the next-token shift happens on
    the *hidden* states (d-wide) before the unembed matmul, and the loss is
    ``logsumexp(logits) − logits[target]`` computed directly — the vocab
    dim stays sharded end-to-end; only [B,S,1]-sized reductions cross the
    mesh instead of fp32 [B,S,V] normalized-logit reshards.
    """
    from .pshard import shard_last
    if "embeds" in batch:
        x, aux = forward_hidden(params, cfg, embeds=batch["embeds"])
        targets = batch["labels"]
    else:
        tokens = batch["tokens"]
        x, aux = forward_hidden(params, cfg, tokens=tokens)
        x = x[:, :-1]
        targets = tokens[:, 1:]
    logits = shard_last(_logits(params, cfg, x))       # [B,S',V] V-sharded
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # target pick as a contraction over the sharded vocab dim (a gather
    # would make GSPMD replicate the fp32 logits; the one-hot dot keeps V
    # sharded and all-reduces only [B,S]-sized partials)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ce = jnp.mean(lse - tgt)
    w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    return ce + w * aux


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            capacity: int | None = None):
    """Process a prompt, returning (last-position logits, caches)."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    capacity = capacity or S
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    caches = init_caches(cfg, B, capacity)
    x, _, caches = _scan_blocks(params, cfg, x, positions, "prefill", caches,
                                capacity)
    return _logits(params, cfg, x[:, -1:]), caches


def decode_step(params, cfg: ArchConfig, token, caches):
    """One-token decode.  token: [B, 1] ids → (logits [B,1,V], caches)."""
    x = _embed(params, cfg, tokens=token)
    x, _, caches = _scan_blocks(params, cfg, x, None, "decode", caches, 0)
    return _logits(params, cfg, x), caches
