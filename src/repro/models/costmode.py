"""Cost-probe mode for roofline accounting.

XLA's HLOCostAnalysis counts a while/scan body ONCE regardless of trip count
(verified: scan of L matmuls reports the flops of one).  The roofline
therefore lowers two extra *cost probes* per (arch × shape): 1- and
2-super-block variants with

  * the layer stack unrolled as a Python loop (no scan), and
  * direct (non-chunked) sequence mixers — the chunked forms hide their
    bodies inside scans; the direct forms materialize abstractly (no
    allocation happens at lowering) and count exactly.

Total-per-device metric M(R) is then reconstructed exactly as
``M(1) + (R−1)·(M(2) − M(1))`` — the difference isolates one super-block
including its collectives; embed/logits/aggregation appear once in both and
cancel.  (sLSTM keeps its true time recurrence — corrected analytically in
benchmarks/roofline.py.)
"""
from __future__ import annotations

import contextlib

_COST_MODE = False


def cost_mode() -> bool:
    return _COST_MODE


@contextlib.contextmanager
def cost_probe():
    global _COST_MODE
    prev = _COST_MODE
    _COST_MODE = True
    try:
        yield
    finally:
        _COST_MODE = prev
