"""Mamba (S6 selective-state-space) block in pure JAX.

Train/prefill use ``jax.lax.associative_scan`` over the sequence (log-depth on
TPU); decode is the O(1) recurrence.  The recurrence per channel c and state n:

    h_t = exp(Δ_t·A) ⊙ h_{t-1} + (Δ_t·B_t)·x_t
    y_t = C_t·h_t + D ⊙ x_t

Cache: (conv tail [B, k-1, di], ssm state [B, di, N]).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init
from .costmode import cost_mode
from .pshard import shard_dim, shard_last


class MambaCache(NamedTuple):
    conv: jax.Array   # [B, k-1, di] last inputs to the causal conv
    ssm: jax.Array    # [B, di, N]


def _dims(cfg: ArchConfig):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return di, dt_rank, cfg.ssm_state, cfg.ssm_conv


def init_mamba(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di, dt_rank, N, k = _dims(cfg)
    ks = jax.random.split(key, 8)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,), jnp.float32)
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log1p(-jnp.exp(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (k, di), jnp.float32)
                   / jnp.sqrt(float(k))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], di, dt_rank + 2 * N, dtype),
        "dt_proj": dense_init(ks[4], dt_rank, di, jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _ssm_inputs(p, cfg: ArchConfig, xc):
    """xc: [B,S,di] post-conv activations → (dA [B,S,di,N], dBx [B,S,di,N],
    C [B,S,N])."""
    di, dt_rank, N, _ = _dims(cfg)
    proj = xc @ p["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                              [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])   # [B,S,di]
    A = -jnp.exp(p["A_log"])                                    # [di,N]
    dA = jnp.exp(dt[..., None] * A)                             # [B,S,di,N]
    dBx = (dt[..., None] * Bc[..., None, :]) * xc.astype(jnp.float32)[..., None]
    return dA, dBx, Cc


def _conv(p, x, cfg: ArchConfig, tail=None):
    """Causal depthwise conv1d.  x: [B,S,di]; tail: [B,k-1,di] or None.

    Train path uses pad() rather than concat(zeros, x) — the concat version
    made GSPMD gather a [B,S-1,di] fp32 slice across the mesh (§Perf iter 3).
    """
    k = cfg.ssm_conv
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))           # [B,S+k-1,di]
    else:
        xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"]), xp[:, -(k - 1):]


def _combine(a, b):
    (a1, b1), (a2, b2) = a, b
    return a1 * a2, a2 * b1 + b2


def mamba_forward(p, cfg: ArchConfig, x, return_cache=False,
                  chunk: int = 128):
    """x: [B,S,d] → y [B,S,d] (+ cache).

    The selective scan is *chunked*: a sequential ``lax.scan`` over S/chunk
    blocks carrying the [B,di,N] state, with a log-depth associative scan
    inside each block.  Never materializes the full [B,S,di,N] tensor
    (68 TB for Jamba at 32k) — peak is O(B·chunk·di·N).
    """
    B, S, d = x.shape
    di, dt_rank, N, k = _dims(cfg)
    xz = shard_last(x @ p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, tail = _conv(p, xin, cfg)
    xc = shard_last(xc)

    c = min(chunk, S)
    if S % c != 0 or cost_mode():  # ragged/test shapes or cost probe
        c = S
    nb = S // c
    xcb = xc.reshape(B, nb, c, di).transpose(1, 0, 2, 3)   # [nb,B,c,di]

    @jax.checkpoint
    def block(h0, xc_blk):
        # rematerialized per-chunk: backward recomputes the chunk's
        # [B,c,di,N] internals from (h0, xc_blk) instead of storing them
        # across all S/c chunks (the difference between ~1 GB and ~100 GB
        # of residuals per Mamba layer at Jamba scale)
        dA, dBx, Cc = _ssm_inputs(p, cfg, xc_blk)          # [B,c,di,N]
        dA = shard_dim(dA, 2)
        dBx = shard_dim(dBx, 2)
        # fold carry into the first element: h_1 = dA_1 h0 + dBx_1
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
        _, h = jax.lax.associative_scan(_combine, (dA, dBx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h, Cc) \
            + p["D"] * xc_blk.astype(jnp.float32)
        return h[:, -1], y

    h0 = shard_dim(jnp.zeros((B, di, N), jnp.float32), 1)
    h_last, yb = jax.lax.scan(block, h0, xcb)
    y = shard_last(yb.transpose(1, 0, 2, 3).reshape(B, S, di))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_cache:
        return out, MambaCache(conv=tail, ssm=h_last)
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> MambaCache:
    di, _, N, k = _dims(cfg)
    return MambaCache(conv=jnp.zeros((batch, k - 1, di), dtype),
                      ssm=jnp.zeros((batch, di, N), jnp.float32))


def mamba_decode(p, cfg: ArchConfig, x, cache: MambaCache):
    """One-token step.  x: [B,1,d]."""
    B = x.shape[0]
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, tail = _conv(p, xin, cfg, tail=cache.conv)
    dA, dBx, Cc = _ssm_inputs(p, cfg, xc)            # S = 1
    h = dA[:, 0] * cache.ssm + dBx[:, 0]             # [B,di,N]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0]) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], MambaCache(conv=tail, ssm=h)
