"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, true recurrence with exponential gating + stabilizer).

mLSTM train/prefill uses the stabilized parallel (attention-like) form;
decode uses the matrix-memory recurrence

    C_t = f' C_{t-1} + i' v_t k_tᵀ,   n_t = f' n_{t-1} + i' k_t,
    h_t = o_t ⊙ (C_t q_t) / max(|n_tᵀ q_t|, exp(-m_t))

with log-space stabilizer m_t.  sLSTM is a lax.scan over time with per-head
block-diagonal recurrent weights.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .costmode import cost_mode
from .layers import dense_init


class MLSTMCache(NamedTuple):
    C: jax.Array   # [B, H, hd, hd]
    n: jax.Array   # [B, H, hd]
    m: jax.Array   # [B, H]


class SLSTMCache(NamedTuple):
    c: jax.Array   # [B, d]
    n: jax.Array   # [B, d]
    h: jax.Array   # [B, d]
    m: jax.Array   # [B, d]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig, dtype):
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {"wq": dense_init(ks[0], d, d, dtype),
            "wk": dense_init(ks[1], d, d, dtype),
            "wv": dense_init(ks[2], d, d, dtype),
            "wi": dense_init(ks[3], d, H, jnp.float32),
            "wf": dense_init(ks[4], d, H, jnp.float32),
            "wog": dense_init(ks[5], d, d, dtype),
            "out": dense_init(ks[6], d, d, dtype)}


def _mlstm_qkv(p, cfg, x):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = (x @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32) / jnp.sqrt(hd)
    v = (x @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    i_t = (x.astype(jnp.float32) @ p["wi"])          # [B,S,H] pre-act
    f_t = (x.astype(jnp.float32) @ p["wf"])
    return q, k, v, i_t, f_t


def mlstm_forward(p, cfg: ArchConfig, x, return_cache=False,
                  chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM.

    ``lax.scan`` over S/chunk blocks carrying the (C, n, m) matrix-memory
    state; within a block the quadratic [B,H,c,c] decay matrix is tiny.
    Equivalent to the paper's parallel form but O(S·c) instead of O(S²).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q, k, v, i_t, f_t = _mlstm_qkv(p, cfg, x)
    logf = -jax.nn.softplus(-f_t)                     # log σ(f̃)  [B,S,H]

    c = min(chunk, S)
    if S % c != 0 or cost_mode():
        c = S
    nb = S // c

    def to_blocks(t):   # [B,S,...] → [nb,B,c,...]
        return t.reshape((B, nb, c) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    qb, kb, vb = to_blocks(q), to_blocks(k), to_blocks(v)
    ib, fb = to_blocks(i_t), to_blocks(logf)

    def block(carry, scanned):
        C0, n0, m0 = carry                             # [B,H,hd,hd] [B,H,hd] [B,H]
        qc, kc, vc, ic, fc = scanned                   # [B,c,H,*]
        F = jnp.cumsum(fc, axis=1)                     # [B,c,H]
        Fh = F.transpose(0, 2, 1)                      # [B,H,c]
        ih = ic.transpose(0, 2, 1)
        # running stabilizer: m_t = F_t + max(m0, cummax_{s≤t}(ĩ_s − F_s))
        u = jax.lax.cummax(ih - Fh, axis=2)
        m = Fh + jnp.maximum(m0[..., None], u)         # [B,H,c]
        # inter-chunk (state) path weight
        w_state = jnp.exp(m0[..., None] + Fh - m)      # [B,H,c]
        # intra-chunk decay D[t,s] = F_t − F_s + ĩ_s − m_t  (s ≤ t)
        D = (Fh[..., :, None] - Fh[..., None, :] + ih[..., None, :]
             - m[..., :, None])                        # [B,H,c,c]
        mask = jnp.tril(jnp.ones((c, c), bool))
        Dp = jnp.where(mask[None, None], jnp.exp(D), 0.0)
        logits = jnp.einsum("bshx,bthx->bhst", qc, kc)  # [B,H,c,c]
        W = logits * Dp
        num = (jnp.einsum("bhst,bthx->bshx", W, vc)
               + jnp.einsum("bhs,bhxy,bshy->bshx",
                            w_state, C0, qc))
        den = (W.sum(-1) + w_state * jnp.einsum("bhy,bshy->bhs", n0, qc)
               ).transpose(0, 2, 1)[..., None]          # [B,c,H,1]
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m).transpose(0, 2, 1)[..., None])
        h = num / den                                   # [B,c,H,hd]
        # state update to end of chunk
        m_end = m[..., -1]                              # [B,H]
        a = Fh[..., -1:] - Fh + ih - m_end[..., None]   # [B,H,c]
        w_s = jnp.exp(a)
        decay0 = jnp.exp(m0 + Fh[..., -1] - m_end)      # [B,H]
        kT = kc.transpose(0, 2, 1, 3)                   # [B,H,c,hd]
        vT = vc.transpose(0, 2, 1, 3)
        C1 = decay0[..., None, None] * C0 \
            + jnp.einsum("bhs,bhsx,bhsy->bhxy", w_s, vT, kT)
        n1 = decay0[..., None] * n0 + jnp.einsum("bhs,bhsx->bhx", w_s, kT)
        return (C1, n1, m_end), h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    (C1, n1, m1), hb = jax.lax.scan(
        block, (C0, n0, m0), (qb, kb, vb, ib, fb))
    hsv = hb.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    o = jax.nn.sigmoid((x @ p["wog"]).astype(jnp.float32)).reshape(B, S, H, hd)
    y = ((o * hsv).reshape(B, S, d)).astype(x.dtype) @ p["out"]
    if not return_cache:
        return y
    return y, MLSTMCache(C=C1, n=n1, m=m1)


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype) -> MLSTMCache:
    H = cfg.n_heads
    hd = cfg.d_model // H
    return MLSTMCache(C=jnp.zeros((batch, H, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, H, hd), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_decode(p, cfg: ArchConfig, x, cache: MLSTMCache):
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q, k, v, i_t, f_t = _mlstm_qkv(p, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]               # [B,H,hd]
    logf = -jax.nn.softplus(-f_t[:, 0])               # [B,H]
    logi = i_t[:, 0]
    m_new = jnp.maximum(logf + cache.m, logi)
    fp = jnp.exp(logf + cache.m - m_new)[..., None]
    ip = jnp.exp(logi - m_new)[..., None]
    C = fp[..., None] * cache.C + ip[..., None] * jnp.einsum("bhx,bhy->bhxy", v, k)
    n = fp * cache.n + ip * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhx,bhx->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    hsv = jnp.einsum("bhxy,bhy->bhx", C, q) / denom
    o = jax.nn.sigmoid((x @ p["wog"]).astype(jnp.float32)).reshape(B, 1, H, hd)
    y = ((o[:, 0] * hsv).reshape(B, 1 * d))[:, None].astype(x.dtype) @ p["out"]
    return y, MLSTMCache(C=C, n=n, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 10)
    p = {"out": dense_init(ks[8], d, d, dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p["w" + g] = dense_init(ks[i], d, d, jnp.float32)
        p["r" + g] = (jax.random.normal(ks[4 + i], (H, hd, hd), jnp.float32)
                      / jnp.sqrt(float(hd)))
        p["b" + g] = jnp.zeros((d,), jnp.float32)
    return p


def _slstm_step(p, cfg: ArchConfig, x_t, cache: SLSTMCache):
    """x_t: [B,d] (pre-projected inputs applied outside for scan efficiency
    would be better; kept simple here)."""
    B, d = x_t.shape
    H = cfg.n_heads
    hd = d // H

    def rec(w, h):
        hh = h.reshape(B, H, hd)
        return jnp.einsum("bhx,hxy->bhy", hh, w).reshape(B, d)

    xf = x_t.astype(jnp.float32)
    z = jnp.tanh(xf @ p["wz"] + rec(p["rz"], cache.h) + p["bz"])
    i_t = xf @ p["wi"] + rec(p["ri"], cache.h) + p["bi"]
    f_t = xf @ p["wf"] + rec(p["rf"], cache.h) + p["bf"]
    o = jax.nn.sigmoid(xf @ p["wo"] + rec(p["ro"], cache.h) + p["bo"])
    logf = -jax.nn.softplus(-f_t)                    # σ-gated forget, log space
    m_new = jnp.maximum(logf + cache.m, i_t)
    fp = jnp.exp(logf + cache.m - m_new)
    ip = jnp.exp(i_t - m_new)
    c = fp * cache.c + ip * z
    n = jnp.maximum(fp * cache.n + ip, jnp.exp(-m_new))
    h = o * (c / n)
    return SLSTMCache(c=c, n=n, h=h, m=m_new)


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype) -> SLSTMCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMCache(c=z, n=jnp.ones_like(z), h=z,
                      m=jnp.zeros((batch, d), jnp.float32))


def slstm_forward(p, cfg: ArchConfig, x, return_cache=False):
    """x: [B,S,d] — lax.scan over time (true nonlinear recurrence)."""
    B, S, d = x.shape
    cache0 = init_slstm_cache(cfg, B, x.dtype)

    def step(cache, x_t):
        cache = _slstm_step(p, cfg, x_t, cache)
        return cache, cache.h

    cache, hs = jax.lax.scan(step, cache0, x.transpose(1, 0, 2))
    y = (hs.transpose(1, 0, 2).astype(x.dtype)) @ p["out"]
    if return_cache:
        return y, cache
    return y


def slstm_decode(p, cfg: ArchConfig, x, cache: SLSTMCache):
    cache = _slstm_step(p, cfg, x[:, 0], cache)
    y = (cache.h[:, None].astype(x.dtype)) @ p["out"]
    return y, cache
