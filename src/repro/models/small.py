"""Small experiment models from the paper (§V-A).

* ``mlp``: the paper's MNIST classifier — one hidden layer, 200 units
  (model size 6.37e6 bits ≈ 199,210 fp32 params: 784·200+200+200·10+10).
* ``cnn``: AlexNet stand-in for the CIFAR-10-like experiments (the paper uses
  AlexNet @ 4.57e8 bits; we use a narrower conv net with the same role —
  documented deviation for a 1-core CPU container).

Functional style: ``init(key) -> params``, ``loss(params, x, y)``,
``accuracy(params, x, y)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _dense_init(key, n_in, n_out):
    k1, k2 = jax.random.split(key)
    scale = jnp.sqrt(2.0 / n_in)
    return {"w": jax.random.normal(k1, (n_in, n_out)) * scale,
            "b": jnp.zeros((n_out,))}


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ---------------------------------------------------------------------------
# MLP (paper's MNIST model)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, dims=(784, 200, 10)):
    keys = jax.random.split(key, len(dims) - 1)
    return [_dense_init(k, i, o) for k, i, o in zip(keys, dims[:-1], dims[1:])]


def mlp_logits(params, x):
    x = x.reshape(x.shape[0], -1)
    for layer in params[:-1]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    last = params[-1]
    return x @ last["w"] + last["b"]


def mlp_loss(params, x, y):
    return cross_entropy(mlp_logits(params, x), y)


def mlp_accuracy(params, x, y):
    return jnp.mean((jnp.argmax(mlp_logits(params, x), -1) == y)
                    .astype(jnp.float32))


def mlp_size_bits(params) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params)) * 32


# ---------------------------------------------------------------------------
# CNN (AlexNet stand-in for CIFAR-like data)
# ---------------------------------------------------------------------------

def _conv_init(key, k, c_in, c_out):
    scale = jnp.sqrt(2.0 / (k * k * c_in))
    return {"w": jax.random.normal(key, (k, k, c_in, c_out)) * scale,
            "b": jnp.zeros((c_out,))}


def init_cnn(key: jax.Array, widths=(32, 64, 128), fc=256, num_classes=10):
    keys = jax.random.split(key, len(widths) + 2)
    params = {"convs": [], "fc1": None, "fc2": None}
    c_in = 3
    for i, w in enumerate(widths):
        params["convs"].append(_conv_init(keys[i], 3, c_in, w))
        c_in = w
    spatial = 32 // (2 ** len(widths))
    params["fc1"] = _dense_init(keys[-2], spatial * spatial * c_in, fc)
    params["fc2"] = _dense_init(keys[-1], fc, num_classes)
    return params


def cnn_logits(params, x):
    for conv in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x, conv["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + conv["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_loss(params, x, y):
    return cross_entropy(cnn_logits(params, x), y)


def cnn_accuracy(params, x, y):
    return jnp.mean((jnp.argmax(cnn_logits(params, x), -1) == y)
                    .astype(jnp.float32))
