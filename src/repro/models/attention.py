"""GQA attention: full-causal / sliding-window for train & prefill, and
single-token decode against a (ring-buffer) KV cache.

Layouts:  q [B,S,H,hd]; k,v [B,S,KV,hd]; cache k/v [B,C,KV,hd] with capacity
C = seq_len (full) or window (sliding).  fp32 softmax throughout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .costmode import cost_mode
from .layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array      # [B, C, KV, hd]
    v: jax.Array      # [B, C, KV, hd]
    pos: jax.Array    # scalar int32 — number of tokens already cached


def init_attn(key, cfg: ArchConfig, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, H * hd, dtype),
         "wk": dense_init(ks[1], d, KV * hd, dtype),
         "wv": dense_init(ks[2], d, KV * hd, dtype),
         "wo": dense_init(ks[3], H * hd, d, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_cache(cfg: ArchConfig, batch: int, capacity: int, dtype) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.hd
    return KVCache(k=jnp.zeros((batch, capacity, KV, hd), dtype),
                   v=jnp.zeros((batch, capacity, KV, hd), dtype),
                   pos=jnp.zeros((), jnp.int32))


def _qkv(p, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, cfg: ArchConfig):
    """q [B,S,H,hd], k [B,T,KV,hd] → scores [B,KV,G,S,T] (G = H/KV)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)


def _attend(scores, v, mask):
    """scores [B,KV,G,S,T], v [B,T,KV,hd] → out [B,S,H,hd]."""
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    B, S, KV, G, hd = out.shape
    return out.reshape(B, S, KV * G, hd)


def _attend_chunked(q, k, v, cfg: ArchConfig, q_chunk: int = 512,
                    kv_chunk: int = 1024):
    """Exact streaming-softmax (flash-style) causal attention in pure jnp.

    Never materializes S×S: memory is O(q_chunk·kv_chunk) per step.  This is
    the lowering/oracle path; the Pallas ``flash_attention`` kernel is the
    TPU production path with the same math.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    nq, nk = S // qc if S % qc == 0 else -1, S // kc if S % kc == 0 else -1
    if nq < 0 or nk < 0 or cost_mode():  # ragged/test shapes or cost probe
        scores = _gqa_scores(q, k, cfg)
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = j <= i
        if cfg.sliding_window is not None:
            mask &= j > i - cfg.sliding_window
        return _attend(scores, v, mask[None, None, None])

    qg = q.reshape(B, nq, qc, KV, G, hd).astype(jnp.float32)
    kg = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vg = v.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def q_block(qi, q_blk):
        # q_blk: [B, qc, KV, G, hd]
        m0 = jnp.full((B, KV, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)

        def kv_block(carry, scanned):
            m, l, acc = carry
            kj, (k_blk, v_blk) = scanned
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_blk, k_blk) * scale
            qpos = qi * qc + jnp.arange(qc)[:, None]
            kpos = kj * kc + jnp.arange(kc)[None, :]
            mask = kpos <= qpos
            if cfg.sliding_window is not None:
                mask &= kpos > qpos - cfg.sliding_window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p_.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqt,btkh->bkgqh",
                                                     p_, v_blk)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), (kg, vg)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,KV,G,qc,hd]
        return out.transpose(0, 3, 1, 2, 4)              # [B,qc,KV,G,hd]

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), qg.transpose(1, 0, 2, 3, 4, 5)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out


def attn_forward(p, cfg: ArchConfig, x, positions):
    """Full-sequence causal (optionally sliding-window) attention."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = _attend_chunked(q, k, v, cfg)
    return (out.reshape(B, S, -1).astype(x.dtype) @ p["wo"]).astype(x.dtype)


def attn_prefill(p, cfg: ArchConfig, x, positions, capacity: int):
    """Forward + build the KV cache (last ``capacity`` positions)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = _attend_chunked(q, k, v, cfg)
    y = (out.reshape(B, S, -1).astype(x.dtype) @ p["wo"]).astype(x.dtype)
    if capacity >= S:
        ck = jnp.pad(k, ((0, 0), (0, capacity - S), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, capacity - S), (0, 0), (0, 0)))
    else:  # keep the most recent window
        ck, cv = k[:, S - capacity:], v[:, S - capacity:]
    cache = KVCache(k=ck, v=cv, pos=jnp.asarray(S, jnp.int32))
    return y, cache


def attn_decode(p, cfg: ArchConfig, x, cache: KVCache):
    """One-token decode: x [B,1,d]; attends to cache + itself."""
    B, _, _ = x.shape
    C = cache.k.shape[1]
    positions = jnp.full((B, 1), cache.pos, jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    # write new k/v into the ring slot pos % C
    slot = jnp.mod(cache.pos, C)
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    scores = _gqa_scores(q, ck, cfg)                    # [B,KV,G,1,C]
    idx = jnp.arange(C)
    valid = idx <= jnp.minimum(cache.pos, C - 1)        # filled slots (ring ⇒ all
    out = _attend(scores, cv, valid[None, None, None, None])  # once pos ≥ C)
    y = (out.reshape(B, 1, -1) @ p["wo"]).astype(x.dtype)
    return y, KVCache(k=ck, v=cv, pos=cache.pos + 1)
