"""Mixture-of-Experts FFN with GShard/Switch-style *grouped* capacity dispatch.

Tokens are split into groups of ``group_size``; each group independently
routes its tokens into per-expert capacity slots (C_g = g·k·cf/E, dropped on
overflow) — the one-hot dispatch tensor is [G, g, E, C_g], i.e. O(g²·k·cf)
per group instead of O(T²·k·cf/E·E) for a monolithic dispatch (43 TB for a
65k-token device batch at Jamba scale; ~0.7 GB grouped).  Groups map to the
data/batch dim at scale, so expert all-to-alls stay within capacity bounds.

The expert axis shards over "model" (expert parallelism: 16/64/128 experts ÷
16-way axis).  Returns (output, aux_loss) with the standard load-balance aux.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .layers import dense_init
from .pshard import shard_dim


def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d, E, ffe = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)

    def expert_stack(k, n_in, n_out):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, n_in, n_out, dtype) for kk in keys])

    return {"router": dense_init(ks[0], d, E, jnp.float32),
            "w1": expert_stack(ks[1], d, ffe),
            "w3": expert_stack(ks[2], d, ffe),
            "w2": expert_stack(ks[3], ffe, d)}


DEFAULT_GROUP = 1024


def capacity(group_tokens: int, m: MoEConfig) -> int:
    c = int(group_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_forward(p, cfg: ArchConfig, x: jax.Array,
                group_size: int = DEFAULT_GROUP):
    """x: [B, S, d] → ([B, S, d], aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E = m.num_experts
    g = min(group_size, T)
    if T % g != 0:
        g = T          # ragged small/test shapes: one group
    G = T // g
    C = capacity(g, m)
    xt = x.reshape(G, g, d)

    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)
    topv, topi = jax.lax.top_k(gates, m.top_k)                 # [G, g, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # --- slot assignment per group, slot-priority order ----------------------
    dispatch = jnp.zeros((G, g, E, C), x.dtype)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for j in range(m.top_k):
        oh = jax.nn.one_hot(topi[:, :, j], E, dtype=jnp.int32)  # [G, g, E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        keep = (pos < C) & (oh > 0)
        posc = jnp.where(keep, pos, 0)
        slot = (jax.nn.one_hot(posc, C, dtype=jnp.float32)
                * keep[..., None])                              # [G, g, E, C]
        dispatch = dispatch + slot.astype(x.dtype)
        combine = combine + slot * topv[:, :, j][:, :, None, None]
        counts = counts + oh.sum(1)

    # --- expert compute (expert-parallel over "model") ------------------------
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xt)             # [G, E, C, d]
    xe = shard_dim(xe, 1)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w1"]))
    h = shard_dim(h, 1) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    ye = shard_dim(jnp.einsum("gecf,efd->gecd", h, p["w2"]), 1)
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)

    # --- load-balance aux loss ------------------------------------------------
    frac = jnp.mean(jax.nn.one_hot(topi[..., 0], E), axis=(0, 1))
    mean_gate = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_gate)
    return out.reshape(B, S, d), aux.astype(jnp.float32)
