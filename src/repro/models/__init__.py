"""Model zoo: small paper models + the generic multi-family decoder stack."""
from . import attention, layers, mamba, moe, small, transformer, xlstm
from .transformer import (decode_step, forward, init_caches, init_params,
                          loss, prefill)

__all__ = ["attention", "layers", "mamba", "moe", "small", "transformer",
           "xlstm", "init_params", "forward", "loss", "prefill",
           "decode_step", "init_caches"]
