"""Activation-sharding hints for GSPMD.

``lax.scan`` + ``jax.checkpoint`` frequently lose sharding propagation for
intermediates (XLA falls back to replicated, exploding temp memory).  These
helpers annotate activations when an ambient mesh is present and degrade to
no-ops in single-device tests/sims.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _mesh_axis_size(name: str) -> int | None:
    # get_abstract_mesh landed after jax 0.4.x; fall back to the thread-local
    # physical mesh on older versions
    _get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    mesh = _get_abstract() if _get_abstract is not None else None
    if mesh is None or mesh.empty or name not in mesh.shape:
        try:
            from jax._src import mesh as mesh_lib
            m = mesh_lib.thread_resources.env.physical_mesh
            if m.empty or name not in m.shape:
                return None
            return m.shape[name]
        except Exception:
            return None
    return mesh.shape[name]


def shard_dim(x: jax.Array, dim: int, axis: str = "model") -> jax.Array:
    """Constrain dimension ``dim`` of x over mesh axis ``axis`` (if the
    ambient mesh has it and the dim divides).

    Other dims stay UNCONSTRAINED — a plain ``None`` would *force
    replication*, making GSPMD insert all-gathers for dims that were happily
    sharded (this exact bug cost 6×16 GB of expert-hidden gathers per Jamba
    MoE layer — EXPERIMENTS.md §Perf iteration 2).
    """
    size = _mesh_axis_size(axis)
    if size is None or x.ndim == 0:
        return x
    d = dim % x.ndim
    if x.shape[d] % size != 0 or x.shape[d] < size:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[d] = axis
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def shard_last(x: jax.Array, axis: str = "model") -> jax.Array:
    return shard_dim(x, -1, axis)
