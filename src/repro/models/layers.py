"""Shared neural building blocks: RMSNorm, RoPE, SwiGLU, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, n_in, n_out, dtype=jnp.float32):
    scale = jnp.sqrt(1.0 / n_in).astype(jnp.float32)
    return (jax.random.normal(key, (n_in, n_out), jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array,
           w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def init_swiglu(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, d, ff, dtype),
            "w3": dense_init(k2, d, ff, dtype),
            "w2": dense_init(k3, ff, d, dtype)}
