"""Checkpointing: pytree ←→ .npz + JSON treedef index.

Arrays are flattened with stable keypath names so checkpoints survive module
refactors that preserve structure; metadata (step, round, energy ledger, rng)
rides along in the JSON sidecar.
"""
from .checkpoint import load_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "load_checkpoint"]
