from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_")


def save_checkpoint(path: str, tree: Any, metadata: dict | None = None) -> None:
    """Write ``path``.npz (arrays) and ``path``.json (structure + metadata)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    keys = []
    for i, (kp, leaf) in enumerate(leaves_with_paths):
        name = f"leaf_{i:05d}"
        arrays[name] = np.asarray(leaf)
        keys.append(_keystr(kp))
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    np.savez(path + ".npz", **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(path + ".json", "w") as f:
        json.dump({"keys": keys, "treedef": str(treedef),
                   "metadata": metadata or {}}, f)


def load_checkpoint(path: str, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (same treedef as when saved)."""
    data = np.load(path + ".npz")
    with open(path + ".json") as f:
        meta = json.load(f)
    leaves = [data[f"leaf_{i:05d}"] for i in range(len(meta["keys"]))]
    treedef = jax.tree_util.tree_structure(like)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves; target structure expects "
            f"{treedef.num_leaves}")
    like_leaves = jax.tree_util.tree_leaves(like)
    restored = [np.asarray(l).astype(ll.dtype) if hasattr(ll, "dtype") else l
                for l, ll in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), meta["metadata"]
