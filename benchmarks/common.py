"""Shared world-building for the paper-replication benchmarks.

Scaled-down defaults (CPU container): MNIST-like synthetic data, 10 clients,
d=5 non-IID, the paper's MLP, SGD lr 0.01, 5 local iterations, batch 10 —
exactly the paper's FL hyperparameters; rounds and dataset size are reduced
(documented per figure).  Set REPRO_FULL=1 for paper-scale rounds.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (AgeBasedScheme, GreedyScheme, ProposedOnline,
                                  RandomScheme)
from repro.data import make_mnist_like, shard_noniid
from repro.fl import SimConfig, run_simulation
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss
from repro.obs.telemetry import env_fingerprint

FULL = os.environ.get("REPRO_FULL", "0") == "1"
ART = os.environ.get("REPRO_ART", "artifacts/bench")

BENCH_SCHEMA = "repro-bench/v1"


@dataclasses.dataclass
class World:
    cell: CellConfig
    clients: list
    test_ds: object
    h: jax.Array          # [K, T]
    pos: jax.Array
    params: object
    rounds: int
    d: int


def build_world(K=10, rounds=None, d=5, seed=0, n_train=None,
                pos_override=None) -> World:
    rounds = rounds or (50 if FULL else 16)
    n_train = n_train or (60_000 if FULL else 5_000)
    tr, te = make_mnist_like(jax.random.PRNGKey(seed), n_train=n_train,
                             n_test=1_000)
    clients = shard_noniid(jax.random.PRNGKey(seed + 1), tr, K, d=d)
    cell = CellConfig(num_clients=K)
    if pos_override is None:
        pos = sample_positions(jax.random.PRNGKey(seed + 2), cell)
    else:
        pos = pos_override
    h = channel_gains(jax.random.PRNGKey(seed + 3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(seed + 4))
    return World(cell, clients, te, h, pos, params, rounds, d)


def run_policy(world: World, policy, seed=0, max_staleness=None,
               aging=False):
    cfg = SimConfig(rounds=world.rounds, local_iters=5, batch_size=10,
                    lr=0.01, eval_every=max(world.rounds // 8, 1), seed=seed,
                    max_staleness=max_staleness, aging_boost=aging)
    t0 = time.time()
    res = run_simulation(world.params, mlp_loss, mlp_accuracy, world.clients,
                         world.test_ds, policy, world.h, world.cell, cfg)
    return res, time.time() - t0


def schemes_matched(world: World, spec: ProblemSpec):
    """The paper's four schemes with matched average participation."""
    from repro.core.selection import average_participants
    proposed = ProposedOnline(spec)
    avg = average_participants(proposed, world.h)
    k = max(1, round(avg))
    K = world.cell.num_clients
    return [proposed,
            RandomScheme(p_bar=min(avg / K, 1.0), num_clients=K),
            GreedyScheme(k=k, num_clients=K),
            AgeBasedScheme(k=k, num_clients=K)], avg


def stamp(payload: dict) -> dict:
    """Attach the shared bench schema + environment fingerprint.  Every
    BENCH_*.json and figure artifact carries the same envelope so
    ``repro.obs.report --diff`` can compare any two of them."""
    out = dict(payload)
    out.setdefault("schema", BENCH_SCHEMA)
    out.setdefault("fingerprint", env_fingerprint())
    out.setdefault("written_unix", time.time())
    return out


def write_bench(path: str, payload: dict):
    """Write a stamped benchmark ledger to ``path`` (the BENCH_*.json
    files at the repo root that CI diffs for regressions)."""
    with open(path, "w") as f:
        json.dump(stamp(payload), f, indent=1, default=float)
    print(f"[bench] wrote {path}")


def save_artifact(name: str, payload: dict):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, name + ".json"), "w") as f:
        json.dump(stamp(payload), f, indent=1, default=float)


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
