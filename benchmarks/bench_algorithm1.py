"""Algorithm 1 microbenchmarks: solver latency, outer-iteration counts,
objective vs naive allocations, online-vs-offline gap, damping ablation."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core import algorithm1 as a1
from repro.core.channel import channel_gains, sample_positions
from repro.core.online import solve_online

from .common import row, save_artifact


def main() -> dict:
    cell = CellConfig(num_clients=10)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=20)
    pos = sample_positions(jax.random.PRNGKey(0), cell)
    h = channel_gains(jax.random.PRNGKey(1), pos, spec.T).T

    out = {}

    # offline solve
    res = a1.solve(h, spec)  # compile
    t0 = time.time()
    n = 5
    for _ in range(n):
        res = jax.block_until_ready(a1.solve(h, spec))
    dt = (time.time() - t0) / n
    naive = float(a1.objective_p1(jnp.full_like(res.p, 0.1),
                                  jnp.full_like(res.w, 0.1), h, spec))
    out["offline"] = {"objective": float(res.objective), "naive_p0.1": naive,
                      "iters": int(res.iters), "residual": float(res.residual),
                      "seconds": dt}
    row("alg1_offline_solve", dt * 1e6,
        f"obj={float(res.objective):.3f};naive={naive:.3f};"
        f"iters={int(res.iters)}")

    # online solve (per-round latency — the deployable path)
    r1 = solve_online(h[:, 0], spec)
    t0 = time.time()
    for t in range(spec.T):
        r1 = jax.block_until_ready(solve_online(h[:, t % spec.T], spec))
    dt = (time.time() - t0) / spec.T
    # offline vs online objective gap (same uniform-p structure comparison)
    p_on = jnp.tile(r1.p[:, None], (1, spec.T))
    w_on = jnp.tile(r1.w[:, None], (1, spec.T))
    obj_on = float(a1.objective_p1(p_on, w_on, h, spec))
    out["online"] = {"per_round_seconds": dt, "objective_lastround": obj_on,
                     "iters": int(r1.iters)}
    row("alg1_online_solve", dt * 1e6,
        f"obj={obj_on:.3f};iters={int(r1.iters)}")

    # damping ablation (the convergence fix documented in EXPERIMENTS.md)
    abl = {}
    for zeta in (0.5, 0.3, 0.1, 0.05):
        r = a1.solve(h, spec, zeta=zeta)
        abl[zeta] = {"residual": float(r.residual),
                     "objective": float(r.objective),
                     "iters": int(r.iters)}
        row(f"alg1_zeta_{zeta}", 0.0,
            f"resid={abl[zeta]['residual']:.2e};obj={abl[zeta]['objective']:.3f}")
    out["damping_ablation"] = abl

    save_artifact("bench_algorithm1", out)
    return out


if __name__ == "__main__":
    main()
