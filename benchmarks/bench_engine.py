"""Engine benchmark: legacy host-loop vs on-device scan engine, plus the
vmap-ed scenario matrix that regenerates the Fig. 6-9 quantities.

Measures, on the K=16 / T=50 MNIST-scale config (paper §V-A hyperparameters):

* ``legacy``  — ``run_simulation_legacy``: host round loop, per-round jit
  dispatch + numpy sync (the pre-refactor engine);
* ``scan``    — the jitted ``lax.scan`` engine via ``make_runner`` (cold call
  includes trace+compile; warm call is the steady-state wall-clock);
* ``matrix``  — ``run_scenario_matrix`` / ``run_seed_matrix``: the paper's
  four schemes over ρ × scenario-lanes × K, one device program per scheme
  (Fig. 6/7: scheme comparison at K sweeps; Fig. 8/9: near/far placements).

Writes ``BENCH_engine.json`` (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.bench_engine [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if "jax" not in sys.modules:
    # expose the host cores as a device mesh so the engine can shard the
    # client axis (must be set before jax initializes; a no-op when the
    # aggregated benchmarks.run harness already imported jax)
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=16").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (AgeBasedScheme, GreedyScheme, ProposedOnline,
                                  RandomScheme, average_participants)
from repro.data import make_mnist_like, shard_noniid
from repro.fl import (SimConfig, make_runner, run_scenario_matrix,
                      run_seed_matrix, run_simulation_legacy)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

from .common import write_bench


def build(K, T, n_train, seed=0):
    tr, te = make_mnist_like(jax.random.PRNGKey(seed), n_train=n_train,
                             n_test=1000)
    clients = shard_noniid(jax.random.PRNGKey(seed + 1), tr, K, d=5)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(seed + 2), cell)
    h = channel_gains(jax.random.PRNGKey(seed + 3), pos, T).T
    params = init_mlp(jax.random.PRNGKey(seed + 4))
    return tr, te, clients, cell, h, params


def lane_gains(cell, T, n_lanes, near_far=True):
    """Scenario-lane channel stack [S, K, T]: uniform placements plus (when
    ``near_far``) the Fig. 8/9 extremes — clients 1-5 near (100-200 m) and at
    the cell edge (900-1000 m)."""
    K = cell.num_clients
    lanes = []
    for s in range(n_lanes):
        pos = sample_positions(jax.random.PRNGKey(100 + s), cell)
        lanes.append(channel_gains(jax.random.PRNGKey(200 + s), pos, T).T)
    if near_far and K > 5:
        sub = CellConfig(num_clients=5)
        rest = sample_positions(jax.random.PRNGKey(77),
                                CellConfig(num_clients=K - 5))
        for s, (lo, hi) in enumerate(((100.0, 200.0), (900.0, 1000.0))):
            special = sample_positions(jax.random.PRNGKey(300 + s), sub,
                                       r_min=lo, r_max=hi)
            pos = jnp.concatenate([special, rest])
            lanes.append(channel_gains(jax.random.PRNGKey(400 + s), pos, T).T)
    return jnp.stack(lanes)


def _time_pair(runner, params, h, legacy_call):
    """(cold, warm) wall-clock for the scan runner and the legacy loop."""
    t0 = time.perf_counter()
    res_scan = runner(params, h)
    scan_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_scan = runner(params, h)
    scan_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_leg = legacy_call()
    legacy_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_leg = legacy_call()
    legacy_warm = time.perf_counter() - t0
    return res_scan, res_leg, scan_cold, scan_warm, legacy_cold, legacy_warm


def bench_wallclock(quick: bool):
    """Old host-loop vs scan engine on the K=16 / T=50 MNIST-scale config.

    Three regimes, all on the same cell/model/energy configuration:

    * ``end_to_end``   — full paper workload (5 local SGD iters, batch 10)
      with the online (P1') policy.  Both engines execute the identical
      training compute, so this ratio is bounded by how much of a round is
      host overhead vs shared GEMMs on the current backend.
    * ``random_policy`` — same, with the closed-form random scheme (no
      per-round solver): isolates the loop overhead from the solver.
    * ``protocol_only`` — ``local_iters=0``: the simulator stack the refactor
      actually moves on-device (policy, Bernoulli draws, Δ_k forcing,
      bandwidth grant, energy ledger, aggregation, broadcast).

    ``speedup`` is the per-round host-overhead elimination implied by the
    measurements: overhead_legacy / overhead_scan where overhead is the
    wall-clock in excess of the shared training compute (measured as the
    scan's training-only time).  The end-to-end ratios are reported raw.
    """
    K, T = (8, 10) if quick else (16, 50)
    n_train = 2_000 if quick else 8_000
    tr, te, clients, cell, h, params = build(K, T, n_train)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=T)

    regimes = {}
    for name, local_iters, pol_name in (
            ("end_to_end", 5, "online"),
            ("random_policy", 5, "random"),
            ("protocol_only", 0, "random")):
        cfg = SimConfig(rounds=T, local_iters=local_iters, batch_size=10,
                        eval_every=max(T // 8, 1), eval_batch=512)
        policy = (ProposedOnline(spec) if pol_name == "online"
                  else RandomScheme(0.15, K))
        runner = make_runner(mlp_loss, mlp_accuracy, clients, te, policy,
                             cell, cfg)
        legacy = lambda: run_simulation_legacy(  # noqa: E731
            params, mlp_loss, mlp_accuracy, clients, te, policy, h, cell, cfg)
        (res_scan, res_leg, scan_cold, scan_warm, legacy_cold,
         legacy_warm) = _time_pair(runner, params, h, legacy)
        regimes[name] = {
            "local_iters": local_iters, "policy": pol_name,
            "legacy_cold_s": legacy_cold, "legacy_warm_s": legacy_warm,
            "scan_cold_s": scan_cold, "scan_warm_s": scan_warm,
            "speedup_warm": legacy_warm / scan_warm,
            "rounds_per_s_scan": T / scan_warm,
            "rounds_per_s_legacy": T / legacy_warm,
            "masks_equal": bool(np.array_equal(res_scan.participation,
                                               res_leg.participation)),
            "final_acc_scan": float(res_scan.test_acc[-1]),
            "final_acc_legacy": float(res_leg.test_acc[-1]),
        }
        print(f"{name:14s} legacy {legacy_warm:6.2f}s  scan {scan_warm:6.2f}s"
              f"  x{legacy_warm / scan_warm:.1f}")

    # host-overhead elimination: per-round wall-clock in excess of the shared
    # workload compute (the protocol-only scan is the measured floor of the
    # non-training protocol stack; training compute cancels in the diff)
    e2e, rnd, proto = (regimes["end_to_end"], regimes["random_policy"],
                       regimes["protocol_only"])
    train_ms = (rnd["scan_warm_s"] - proto["scan_warm_s"]) / T * 1e3
    over_leg = rnd["legacy_warm_s"] / T * 1e3 - train_ms
    over_scan = max(proto["scan_warm_s"] / T * 1e3, 1e-3)
    rec = {
        "config": {"K": K, "T": T, "batch_size": 10, "n_train": n_train,
                   "backend": jax.default_backend(),
                   "devices": len(jax.devices())},
        "regimes": regimes,
        "shared_training_compute_ms_per_round": train_ms,
        "legacy_host_overhead_ms_per_round": over_leg,
        "scan_protocol_ms_per_round": over_scan,
        # headline: best measured END-TO-END wall-clock ratio on this config
        # (warm legacy / warm scan, identical work in both engines; the
        # regime it came from is named so the number can't be misread)
        "speedup": max(e2e["speedup_warm"], rnd["speedup_warm"]),
        "speedup_regime": ("end_to_end" if e2e["speedup_warm"]
                           >= rnd["speedup_warm"] else "random_policy"),
        "speedup_end_to_end_online": e2e["speedup_warm"],
        "speedup_end_to_end_random": rnd["speedup_warm"],
        "speedup_simulator_overhead": over_leg / over_scan,
        "note": "end-to-end ratios share identical training + solver "
                "compute in both engines; the online regime is bounded by "
                "that shared compute on CPU, the overhead figure isolates "
                "the host round-trip cost the scan removes",
    }
    print(f"end-to-end speedup x{rec['speedup']:.1f} "
          f"({rec['speedup_regime']}; online x"
          f"{rec['speedup_end_to_end_online']:.1f}, simulator-overhead x"
          f"{rec['speedup_simulator_overhead']:.1f}, shared training "
          f"{train_ms:.1f} ms/round identical in both engines)")
    return rec


def bench_matrix(quick: bool):
    """Figs. 6-9 in vmapped device programs: ρ × lanes per K, four schemes."""
    out = {}
    T = 10 if quick else 16
    n_train = 2_000 if quick else 5_000
    rhos = [0.05, 0.2] if quick else [0.01, 0.05, 0.2]
    n_seed_lanes = 1 if quick else 3
    K_values = [10] if quick else [10, 20, 30]
    for K in K_values:
        tr, te, clients, cell, h, params = build(K, T, n_train)
        spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=T)
        cfg = SimConfig(rounds=T, local_iters=5, batch_size=10,
                        eval_every=max(T // 4, 1), eval_batch=512)
        h_stack = lane_gains(cell, T, n_seed_lanes)
        S = h_stack.shape[0]
        seeds = list(range(S))

        t0 = time.perf_counter()
        prop = run_scenario_matrix(params, mlp_loss, mlp_accuracy, clients,
                                   te, spec, h_stack, rhos, cfg, seeds)
        prop_s = time.perf_counter() - t0

        avg = average_participants(ProposedOnline(spec), h_stack[0])
        k = max(1, round(avg))
        baselines = [RandomScheme(min(avg / K, 1.0), K),
                     GreedyScheme(k, K), AgeBasedScheme(k, K)]
        schemes = {}
        base_s = 0.0
        for pol in baselines:
            t0 = time.perf_counter()
            m = run_seed_matrix(params, mlp_loss, mlp_accuracy, clients, te,
                                pol, h_stack, cell, cfg, seeds)
            base_s += time.perf_counter() - t0
            e = m.energy
            gini = np.abs(e[:, :, None] - e[:, None, :]).sum((1, 2)) \
                / (2 * K * np.maximum(e.sum(1), 1e-9))
            schemes[pol.name] = {
                "final_acc": m.acc[:, -1].tolist(),
                "total_energy_j": e.sum(1).tolist(),
                "energy_gini": gini.tolist(),
                "participation_per_client": m.participation.sum(1).tolist(),
            }
        e = prop.energy  # [R, S, K]
        out[f"K{K}"] = {
            "rhos": rhos, "lanes": S, "avg_participants": avg,
            "matched_k": k,
            "proposed": {
                "final_acc": prop.acc[..., -1].tolist(),
                "total_energy_j": e.sum(-1).tolist(),
                "mean_participants_per_round":
                    prop.participation.mean((2, 3)).__mul__(K).tolist(),
            },
            "schemes": schemes,
            "wall_s_proposed_matrix": prop_s,
            "wall_s_baselines": base_s,
            "device_programs": 1 + len(baselines),
            "simulations_covered": len(rhos) * S + len(baselines) * S,
        }
        print(f"K={K}: proposed ρ-matrix ({len(rhos)}×{S} sims) "
              f"{prop_s:.2f}s; baselines {base_s:.2f}s")
    return out


def main_quick():
    """Entry point for the aggregated ``benchmarks.run`` harness."""
    payload = {"quick": True,
               "wallclock": bench_wallclock(True),
               "scenario_matrix": bench_matrix(True)}
    write_bench("BENCH_engine.json", payload)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()

    payload = {
        "quick": args.quick,
        "wallclock": bench_wallclock(args.quick),
        "scenario_matrix": bench_matrix(args.quick),
    }
    write_bench(args.out, payload)


if __name__ == "__main__":
    main()
