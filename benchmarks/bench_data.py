"""Data-path benchmark: host pre-stack vs on-device gather vs streaming.

Runs the scan engine on the K=16 MNIST-scale config through the three data
paths at T ∈ {50, 500, 2000} and records wall-clock plus memory:

* ``prestack`` — legacy ``stack_round_batches``: [T, K, L, B, 784] built
  host-side before the scan (footprint grows linearly in T — ~125 MB at
  T=50/L=5, ~1 GB at T=2000/L=1);
* ``device``   — ``DeviceDataStore``: padded [K, N_max, 784] blocks resident
  on device, minibatches gathered inside the scan from the
  ``fold_in(data_key, t)`` stream (footprint independent of T);
* ``stream``   — host-resident blocks, double-buffered ``device_put``
  round-chunk prefetch (device footprint: two chunks, independent of T and
  of the dataset size).

``data_prep_s`` is what each path pays before the first round can run
(stack / pack / first chunk); ``end_to_end_s`` = prep + warm run, the
steady-state cost of a fresh configuration.  ``host_peak_mb`` is the
tracemalloc peak over prep + cold run.  T ≥ 500 drops local_iters to 1 so
the pre-stack reference stays materializable; each T block is
like-for-like across paths.

Writes ``BENCH_data.json`` (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.bench_data [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
import tracemalloc

import jax

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import RandomScheme
from repro.data import from_client_datasets, make_mnist_like, shard_noniid
from repro.data.device import estimate_store_bytes
from repro.fl import SimConfig, make_runner, stack_round_batches
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

from .common import write_bench


def build_world(K, T, n_train, seed=0):
    tr, te = make_mnist_like(jax.random.PRNGKey(seed), n_train=n_train,
                             n_test=1000)
    clients = shard_noniid(jax.random.PRNGKey(seed + 1), tr, K, d=5)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(seed + 2), cell)
    h = channel_gains(jax.random.PRNGKey(seed + 3), pos, T).T
    params = init_mlp(jax.random.PRNGKey(seed + 4))
    return clients, te, cell, h, params


def _bench_path(path, clients, te, cell, h, params, cfg):
    """One path at one config: prep bytes/time, cold+warm run, host peak."""
    policy = RandomScheme(0.15, cell.num_clients)
    tracemalloc.start()
    t0 = time.perf_counter()
    if path == "prestack":
        xb_all, yb_all = stack_round_batches(clients, cfg)
        jax.block_until_ready(xb_all)
        data_bytes = int(xb_all.nbytes + yb_all.nbytes)
        del xb_all, yb_all  # the runner re-stacks; measured separately
    elif path == "device":
        store = from_client_datasets(clients)
        jax.block_until_ready(store.x)
        data_bytes = store.nbytes
        del store
    else:  # stream: devices hold ≤ 2 chunks at a time
        C = min(cfg.stream_chunk, cfg.rounds)
        sample = clients[0].x.shape[1:]
        import numpy as np
        per_round = (cfg.local_iters * cfg.batch_size
                     * int(np.prod(sample)) * 4
                     + cfg.local_iters * cfg.batch_size * 4)
        data_bytes = 2 * C * len(clients) * per_round
    prep_s = time.perf_counter() - t0

    runner = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                         cfg, data_path=path)
    t0 = time.perf_counter()
    res = runner(params, h)
    cold_s = time.perf_counter() - t0
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    t0 = time.perf_counter()
    res = runner(params, h)
    warm_s = time.perf_counter() - t0
    return {
        "data_prep_s": prep_s,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "end_to_end_s": prep_s + warm_s,
        "steps_per_s_warm": cfg.rounds / warm_s,
        "device_data_bytes": data_bytes,
        "host_peak_mb": host_peak / 1e6,
        "final_acc": float(res.test_acc[-1]),
    }


def bench(quick: bool):
    K = 8 if quick else 16
    n_train = 2_000 if quick else 8_000
    horizons = (20, 60) if quick else (50, 500, 2000)
    out = {"config": {"K": K, "n_train": n_train, "batch_size": 10,
                      "backend": jax.default_backend()},
           "horizons": {}}
    clients_cache = {}
    for T in horizons:
        # L=5 is the paper's MNIST config; T ≥ 500 drops to L=1 so the
        # pre-stack reference stays materializable at like-for-like configs
        L = 5 if T <= 100 else 1
        if n_train not in clients_cache:
            clients_cache[n_train] = build_world(K, max(horizons), n_train)
        clients, te, cell, h_full, params = clients_cache[n_train]
        h = h_full[:, :T]
        cfg = SimConfig(rounds=T, local_iters=L, batch_size=10,
                        eval_every=max(T // 4, 1), eval_batch=512,
                        stream_chunk=max(T // 8, 16))
        rec = {"local_iters": L,
               "store_bytes": estimate_store_bytes(clients)}
        for path in ("prestack", "device", "stream"):
            rec[path] = _bench_path(path, clients, te, cell, h, params, cfg)
            print(f"T={T:5d} {path:9s} prep {rec[path]['data_prep_s']:7.2f}s"
                  f"  warm {rec[path]['warm_s']:7.2f}s"
                  f"  end-to-end {rec[path]['end_to_end_s']:7.2f}s"
                  f"  data {rec[path]['device_data_bytes'] / 1e6:8.1f} MB"
                  f"  host-peak {rec[path]['host_peak_mb']:8.1f} MB")
        rec["device_vs_prestack_steps"] = (
            rec["device"]["steps_per_s_warm"]
            / rec["prestack"]["steps_per_s_warm"])
        rec["device_vs_prestack_end_to_end"] = (
            rec["prestack"]["end_to_end_s"] / rec["device"]["end_to_end_s"])
        out["horizons"][f"T{T}"] = rec
    # the headline claim: device data bytes do not grow with T
    sizes = [out["horizons"][f"T{t}"]["device"]["device_data_bytes"]
             for t in horizons]
    out["device_bytes_T_independent"] = len(set(sizes)) == 1
    pre = [out["horizons"][f"T{t}"]["prestack"]["device_data_bytes"]
           for t in horizons]
    out["prestack_bytes_by_T"] = dict(zip([f"T{t}" for t in horizons], pre))
    return out


def _write(payload, out_path):
    write_bench(out_path, payload)


def main_quick():
    """Entry point for the aggregated ``benchmarks.run`` harness."""
    payload = {"quick": True, **bench(True)}
    _write(payload, "BENCH_data.json")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--out", default="BENCH_data.json")
    args = ap.parse_args()
    payload = {"quick": args.quick, **bench(args.quick)}
    _write(payload, args.out)


if __name__ == "__main__":
    main()
