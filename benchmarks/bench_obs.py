"""Observability overhead benchmark: tapped vs untapped per-round cost.

Runs the dense scan engine and the sparse two-phase engine twice each —
metrics taps disabled (``cfg.metrics=None``) and the full default tap set
(``MetricsSpec()``) — and records warm per-round wall-clock for both.
The acceptance bound for the default tap set is ≤ 1.10× the untapped
path; the measured ratio lands in ``BENCH_obs.json`` so
``repro.obs.report --diff`` can gate regressions against it.

Also exercises the host-side telemetry layer end to end: the
``timed_compile`` trace/lower/compile stage spans, run manifests (set
``REPRO_OBS_DIR`` to persist ``runs.jsonl``), and the compile-cache
hit/miss counters around the sparse train cache.

Writes ``BENCH_obs.json`` (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.bench_obs [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import CellConfig
from repro.core.selection import RandomScheme, participant_bucket
from repro.fl import SimConfig, make_runner
from repro.fl.sparse import make_sparse_runner
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss
from repro.obs import MetricsSpec, metrics_summary
from repro.obs.telemetry import get_telemetry, timed_compile

from .bench_sparse import (DIM, build_store, gains, store_clients,
                           test_set)
from .common import write_bench

BOUND = 1.10      # acceptance: default tap set ≤ 1.10× untapped per-round


def _warm_per_round(runner, params, h, T: int, reps: int = 3) -> dict:
    t0 = time.perf_counter()
    res = runner(params, h)
    cold_s = time.perf_counter() - t0
    warm = []
    for _ in range(reps):
        t1 = time.perf_counter()
        res = runner(params, h)
        warm.append(time.perf_counter() - t1)
    return {"cold_s": cold_s, "warm_s": min(warm),
            "per_round_ms": min(warm) / T * 1e3}, res


def _pair(make, T: int, reps: int) -> dict:
    """Build + time the untapped and tapped variants of one path."""
    out = {}
    res_tapped = None
    for name, spec in (("untapped", None), ("tapped", MetricsSpec())):
        runner, params, h = make(spec)
        out[name], res = _warm_per_round(runner, params, h, T, reps)
        if name == "tapped":
            res_tapped = res
    out["overhead_ratio"] = (out["tapped"]["warm_s"]
                             / max(out["untapped"]["warm_s"], 1e-12))
    out["bound"] = BOUND
    out["within_bound"] = out["overhead_ratio"] <= BOUND
    out["metrics_summary"] = metrics_summary(res_tapped.metrics)
    return out


def bench(quick: bool) -> dict:
    E = 6
    T = 10 if quick else 40
    K_dense = 32 if quick else 128
    K_sparse = 256 if quick else 4096
    reps = 3 if quick else 5
    te = test_set()
    params = init_mlp(jax.random.PRNGKey(4), dims=(DIM, 16, te.num_classes))
    base = dict(rounds=T, local_iters=2, batch_size=4, eval_every=T,
                eval_batch=64, local_mode="participants",
                data_stream="client", data_path="device")

    def make_dense(spec):
        store = build_store(K_dense)
        cfg = SimConfig(**base, participation="dense", metrics=spec)
        runner = make_runner(mlp_loss, mlp_accuracy, store_clients(store),
                             te, RandomScheme(p_bar=E / K_dense,
                                              num_clients=K_dense),
                             CellConfig(num_clients=K_dense), cfg)
        return runner, params, gains(K_dense, T)

    def make_sparse(spec):
        store = build_store(K_sparse)
        bucket = participant_bucket(E, cap=K_sparse)
        cfg = SimConfig(**base, participation="sparse",
                        participant_bucket=bucket, metrics=spec)
        runner = make_sparse_runner(mlp_loss, mlp_accuracy, store, te,
                                    RandomScheme(p_bar=E / K_sparse,
                                                 num_clients=K_sparse),
                                    CellConfig(num_clients=K_sparse), cfg)
        return runner, params, gains(K_sparse, T)

    out = {"config": {"E": E, "T": T, "K_dense": K_dense,
                      "K_sparse": K_sparse, "reps": reps,
                      "backend": jax.default_backend()}}
    out["dense"] = _pair(make_dense, T, reps)
    print(f"dense  K={K_dense}: tapped/untapped = "
          f"{out['dense']['overhead_ratio']:.3f} (bound {BOUND})")
    out["sparse"] = _pair(make_sparse, T, reps)
    print(f"sparse K={K_sparse}: tapped/untapped = "
          f"{out['sparse']['overhead_ratio']:.3f} (bound {BOUND})")

    # timed_compile stage spans on a representative jitted function
    f = jax.jit(lambda x: jnp.tanh(x @ x.T).sum())
    timed_compile(f, jnp.ones((64, 64)), label="obs.demo")

    tel = get_telemetry()
    snap = tel.snapshot()
    out["timed_compile_demo"] = {
        k: v for k, v in snap["spans"].items() if k.startswith("obs.demo")}
    out["telemetry"] = {
        "counters": snap["counters"],
        "spans": snap["spans"],
        "manifests_emitted": len(tel.manifests),
    }
    return out


def main_quick():
    """Entry point for the aggregated ``benchmarks.run`` harness."""
    payload = {"quick": True, **bench(True)}
    write_bench("BENCH_obs.json", payload)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    payload = {"quick": args.quick, **bench(args.quick)}
    write_bench(args.out, payload)


if __name__ == "__main__":
    main()
