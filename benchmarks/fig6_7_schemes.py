"""Paper Fig. 6 & 7: asynchronous-FL accuracy vs energy for the four schemes
(proposed / random / greedy / age-based) at matched average participation.

Claim under test: proposed reaches the highest accuracy per Joule; random is
worst.  (Fig. 6: ~1-2 participants/round with K=10; Fig. 7: K ∈ {20, 30}.)
"""
from __future__ import annotations

import numpy as np

from repro.core import ProblemSpec

from .common import build_world, row, run_policy, save_artifact, schemes_matched


def run_setting(world, rho):
    spec = ProblemSpec(cell=world.cell, rho=rho, num_rounds=world.rounds)
    schemes, avg = schemes_matched(world, spec)
    recs = []
    for s in schemes:
        res, secs = run_policy(world, s)
        recs.append({
            "scheme": s.name,
            "final_acc": float(res.test_acc[-1]),
            "acc_curve": [float(a) for a in res.test_acc],
            "energy_curve": [float(res.energy_timeline[r])
                             for r in res.eval_rounds],
            "total_energy_j": float(res.energy_per_client.sum()),
        })
        row(f"fig6_{s.name}", secs / world.rounds * 1e6,
            f"acc={recs[-1]['final_acc']:.3f};"
            f"energy_j={recs[-1]['total_energy_j']:.2f}")
    return {"avg_participants": avg, "schemes": recs}


def main() -> dict:
    out = {}
    world = build_world(K=10)
    out["fig6_k10"] = run_setting(world, rho=0.05)
    for K in (20, 30):
        world = build_world(K=K)
        out[f"fig7_k{K}"] = run_setting(world, rho=0.05)
    save_artifact("fig6_7_schemes", out)
    return out


if __name__ == "__main__":
    main()
