"""Paper Fig. 6 & 7 head-to-head: convergence vs energy for the full
async-FL scheme panel — the paper's probabilistic selection against
FedAsync-style staleness mixing (hinge/poly s(Δτ)), CSMAAFL-style
importance-weighted aggregation, and age-aware scheduling — at matched
average participation, across non-IID severities.

Runs on :func:`repro.fl.schemes.run_scheme_matrix`: schemes × seeds ×
severities ride vmap axes of ONE compiled device program per execution
path (dense scan and sparse two-phase), replacing the old per-scheme
legacy host loop.  Emits ``BENCH_schemes.json``.

    python -m benchmarks.fig6_7_schemes [--quick] [--dense-only] [--out NAME]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (age_aware_policy, average_participants,
                                  csma_policy, online_policy, random_policy)
from repro.data import make_mnist_like, shard_noniid
from repro.data.device import from_client_datasets
from repro.fl import AggregatorConfig, SimConfig
from repro.fl.schemes import SchemeSpec, run_scheme_matrix

from .common import FULL, row, save_artifact, write_bench

SEVERITIES = (2, 5)            # non-IID shards per client (lower = harsher)


def matched_panel(spec: ProblemSpec, h, K: int) -> tuple[list, float]:
    """The comparison panel at matched average participation: every
    baseline is budgeted to the paper scheme's expected transmitting mass
    (paper §V-A methodology) so energy per round is comparable."""
    proposed = online_policy(spec)
    avg = average_participants(proposed, h)
    k = max(1, round(avg))
    p_bar = min(avg / K, 1.0)
    return [
        SchemeSpec("paper", proposed, AggregatorConfig(kind="paper")),
        SchemeSpec("fedasync-hinge", random_policy(p_bar, K),
                   AggregatorConfig(kind="fedasync", staleness_fn="hinge")),
        SchemeSpec("fedasync-poly", random_policy(p_bar, K),
                   AggregatorConfig(kind="fedasync", staleness_fn="poly")),
        SchemeSpec("csmaafl", csma_policy(k, K),
                   AggregatorConfig(kind="csmaafl")),
        SchemeSpec("age-aware", age_aware_policy(k, K),
                   AggregatorConfig(kind="age")),
    ], float(avg)


def build_matrix_world(K: int, rounds: int, n_train: int, seeds, dim=None):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=n_train,
                             n_test=1_000)
    if dim is not None:
        from repro.data import Dataset
        tr = Dataset(tr.x[:, :dim], tr.y, tr.num_classes)
        te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    severity_clients = [shard_noniid(jax.random.PRNGKey(1), tr, K, d=d)
                        for d in SEVERITIES]
    pad = max(int(c.y.shape[0]) for cs in severity_clients for c in cs)
    stores = [from_client_datasets(cs, pad_to=pad)
              for cs in severity_clients]
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h_stack = jnp.stack([
        channel_gains(jax.random.PRNGKey(3 + s), pos, rounds).T
        for s in range(len(seeds))])                    # [S, K, T]
    return stores, te, cell, h_stack


def run_setting(K: int, rho: float, rounds: int, n_train: int, seeds,
                local_iters: int, paths, params, test_ds_dim=None) -> dict:
    stores, te, cell, h_stack = build_matrix_world(K, rounds, n_train,
                                                   seeds, dim=test_ds_dim)
    from repro.models.small import mlp_accuracy, mlp_loss
    spec = ProblemSpec(cell=cell, rho=rho, num_rounds=rounds)
    panel, avg = matched_panel(spec, h_stack[0], K)
    cfg = SimConfig(rounds=rounds, local_iters=local_iters, batch_size=10,
                    lr=0.01, eval_every=max(rounds // 8, 1),
                    local_mode="participants", data_path="device",
                    data_stream="client")
    setting = {"avg_participants": avg, "severities_d": list(SEVERITIES),
               "seeds": list(seeds), "schemes": {}, "paths": {}}
    for path in paths:
        t0 = time.time()
        res = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                                panel, h_stack, cell, cfg, seeds,
                                participation=path)
        secs = time.time() - t0
        setting["paths"][path] = {"wall_s": secs}
        lanes = res.acc.shape[0] * res.acc.shape[1] * res.acc.shape[2]
        row(f"schemes_{path}_k{K}", secs / lanes * 1e6,
            f"lanes={lanes};rounds={rounds}")
        ev = np.asarray(res.eval_rounds).astype(int)
        for v, d in enumerate(SEVERITIES):
            for l, name in enumerate(res.schemes):
                rec = setting["schemes"].setdefault(name, {})
                et = np.asarray(res.energy_timeline[v, l]).mean(axis=0)
                rec[f"d{d}/{path}"] = {
                    # seed-averaged convergence-vs-energy curves
                    "acc_curve": np.asarray(res.acc[v, l]).mean(0).tolist(),
                    "loss_curve": np.asarray(res.loss[v, l]).mean(0).tolist(),
                    "energy_curve": et[ev].tolist(),
                    "final_acc": float(np.asarray(res.acc)[v, l, :, -1]
                                       .mean()),
                    "total_energy_j": float(np.asarray(res.energy)[v, l]
                                            .sum(-1).mean()),
                }
        setting["eval_rounds"] = ev.tolist()
    return setting


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke: short horizon, one seed")
    ap.add_argument("--dense-only", action="store_true",
                    help="skip the sparse two-phase path")
    ap.add_argument("--out", default="BENCH_schemes",
                    help="artifact name (default BENCH_schemes)")
    args = ap.parse_args(argv)

    from repro.models.small import init_mlp
    if args.quick:
        rounds, n_train, seeds, iters, dim = 8, 1_500, [0], 2, 32
        params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 16, 10))
    else:
        rounds = 50 if FULL else 16
        n_train = 60_000 if FULL else 5_000
        seeds, iters, dim = [0, 1], 5, None
        params = init_mlp(jax.random.PRNGKey(4))
    paths = ["dense"] if args.dense_only else ["dense", "sparse"]

    out = {"quick": bool(args.quick)}
    out["fig6_k10"] = run_setting(10, 0.05, rounds, n_train, seeds, iters,
                                  paths, params, test_ds_dim=dim)
    if not args.quick:
        for K in (20, 30):
            out[f"fig7_k{K}"] = run_setting(K, 0.05, rounds, n_train, seeds,
                                            iters, paths, params,
                                            test_ds_dim=dim)
    save_artifact(args.out, out)
    write_bench(f"{args.out}.json", out)         # root copy for CI upload
    return out


if __name__ == "__main__":
    main()
