"""Paper Fig. 8 & 9: extreme client placements.

Scenario 1: clients 1-5 near the server (100-200 m); Scenario 2: clients 1-5
at the cell edge (900-1000 m); remaining clients uniform.

Claims under test: greedy collapses (always picks the same well-placed
clients → unfair participation → accuracy drop, even below random on MNIST);
proposed keeps top accuracy, AND its per-client energy is balanced
(fairness) while total energy stays lowest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import sample_positions

from .common import build_world, row, run_policy, save_artifact, schemes_matched


def scenario_positions(key, K, near: bool):
    cell = CellConfig(num_clients=5)
    r = (100.0, 200.0) if near else (900.0, 1000.0)
    special = sample_positions(key, cell, r_min=r[0], r_max=r[1])
    rest = sample_positions(jax.random.PRNGKey(77),
                            CellConfig(num_clients=K - 5))
    return jnp.concatenate([special, rest])


def run_scenario(name, near):
    K = 10
    pos = scenario_positions(jax.random.PRNGKey(5), K, near)
    world = build_world(K=K, pos_override=pos)
    spec = ProblemSpec(cell=world.cell, rho=0.05, num_rounds=world.rounds)
    schemes, avg = schemes_matched(world, spec)
    recs = []
    for s in schemes:
        res, secs = run_policy(world, s)
        e = res.energy_per_client
        fairness = float(e.max() / max(e[e > 0].min() if (e > 0).any()
                                       else 1.0, 1e-9))
        gini = float(np.abs(e[:, None] - e[None, :]).sum()
                     / (2 * K * max(e.sum(), 1e-9)))
        recs.append({"scheme": s.name,
                     "final_acc": float(res.test_acc[-1]),
                     "total_energy_j": float(e.sum()),
                     "per_client_energy": [float(x) for x in e],
                     "participation_per_client":
                         [float(x) for x in res.participation.sum(0)],
                     "energy_gini": gini, "max_min_ratio": fairness})
        row(f"{name}_{s.name}", secs / world.rounds * 1e6,
            f"acc={recs[-1]['final_acc']:.3f};"
            f"energy_j={recs[-1]['total_energy_j']:.2f};"
            f"gini={gini:.3f}")
    return {"avg_participants": avg, "schemes": recs}


def main() -> dict:
    out = {"scenario1_near": run_scenario("fig8_s1", near=True),
           "scenario2_far": run_scenario("fig8_s2", near=False)}
    save_artifact("fig8_9_scenarios", out)
    return out


if __name__ == "__main__":
    main()
