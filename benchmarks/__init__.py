"""Benchmark suite: paper figures 2-9, Algorithm-1, kernels, roofline."""
