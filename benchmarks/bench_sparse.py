"""Sparse-participation benchmark: population sweep at fixed transmitting mass.

Sweeps the population K with the *expected transmitting count* pinned
(``p̄ = E/K``), so every configuration does the same amount of useful
training work per round; what changes is how much population-shaped overhead
rides along:

* ``dense``  — the [K]-shaped round transition (participants local mode):
  gathers a ``[K, L, B, ...]`` round batch and runs local SGD over all K
  lanes every round, masking non-participants.  Measured at the smaller K
  only (its cost grows linearly with the population).
* ``sparse`` — the participant-centric two-phase path
  (:mod:`repro.fl.sparse`): the [K]-vector decision scan plus a
  bucket-shaped training program shared by the whole sweep (the phase-B
  trace counter is recorded to prove one compile serves every K).

The headline acceptance: sparse per-round wall-clock at K = 10⁵ stays
within 2× of the dense baseline at K = 10³ — per-participant cost, one
hundred times the population.  Memory is reported analytically (resident
store bytes, per-round gather bytes dense vs sparse) plus the tracemalloc
host peak.

Writes ``BENCH_sparse.json`` (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.bench_sparse [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig
from repro.core.selection import RandomScheme, participant_bucket
from repro.data.device import DeviceDataStore
from repro.data.synthetic import Dataset
from repro.fl import SimConfig, make_runner
from repro.fl import sparse as sparse_mod
from repro.fl.sparse import make_sparse_runner
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

from .common import write_bench

DIM, N_PER, CLASSES = 8, 4, 10


def build_store(K: int, seed: int = 0) -> DeviceDataStore:
    """Tiny fixed-size per-client shards, built vectorized (no K-length
    Python loop — at K = 10⁶ a Dataset list is itself the bottleneck)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((K, N_PER, DIM), dtype=np.float32)
    y = np.tile(np.arange(N_PER, dtype=np.int32) % CLASSES, (K, 1))
    return DeviceDataStore(jnp.asarray(x), jnp.asarray(y),
                           jnp.full((K,), N_PER, jnp.int32))


def store_clients(store: DeviceDataStore) -> list:
    """Dataset-list view of a store (dense-path input; small K only)."""
    return [Dataset(store.x[k], store.y[k], CLASSES)
            for k in range(store.num_clients)]


def test_set(seed: int = 99) -> Dataset:
    rng = np.random.default_rng(seed)
    return Dataset(jnp.asarray(rng.standard_normal((64, DIM), np.float32)),
                   jnp.asarray(np.arange(64, dtype=np.int32) % CLASSES),
                   CLASSES)


def gains(K: int, T: int, seed: int = 5) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(1e-14, 1e-12, (K, T)).astype(np.float32))


def _timed_runs(runner, params, h, T: int):
    tracemalloc.start()
    t0 = time.perf_counter()
    res = runner(params, h)
    cold_s = time.perf_counter() - t0
    _, host_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    warm = []
    for _ in range(2):
        t1 = time.perf_counter()
        runner(params, h)
        warm.append(time.perf_counter() - t1)
    warm_s = min(warm)
    return {
        "cold_s": cold_s,
        "warm_s": warm_s,
        "per_round_ms": warm_s / T * 1e3,
        "host_peak_mb": host_peak / 1e6,
        "final_acc": float(res.test_acc[-1]),
        "mean_tx_per_round": float(res.participation.sum(axis=1).mean()),
    }


def bench(quick: bool) -> dict:
    E = 8 if quick else 16                      # expected transmitters/round
    T = 6 if quick else 20
    Ks = (256, 2048) if quick else (10 ** 3, 10 ** 4, 10 ** 5, 10 ** 6)
    K_dense = Ks[0]
    bucket = participant_bucket(E, cap=min(Ks))
    base = dict(rounds=T, local_iters=2, batch_size=4, eval_every=T,
                eval_batch=64, local_mode="participants",
                data_stream="client", data_path="device")
    te = test_set()
    params = init_mlp(jax.random.PRNGKey(4), dims=(DIM, 16, CLASSES))
    out = {"config": {"E": E, "T": T, "bucket": bucket, "Ks": list(Ks),
                      "K_dense_baseline": K_dense, "dim": DIM,
                      "n_per_client": N_PER,
                      "backend": jax.default_backend()},
           "dense": {}, "sparse": {}}

    # --- dense baseline(s): [K]-shaped rounds, small populations only ------
    for K in [k for k in Ks if k <= max(K_dense, 10 ** 4)]:
        store = build_store(K)
        cell = CellConfig(num_clients=K)
        cfg = SimConfig(**base, participation="dense")
        runner = make_runner(mlp_loss, mlp_accuracy, store_clients(store),
                             te, RandomScheme(p_bar=E / K, num_clients=K),
                             cell, cfg)
        rec = _timed_runs(runner, params, gains(K, T), T)
        rec["store_mb"] = store.nbytes / 1e6
        rec["round_gather_mb"] = K * 2 * 4 * DIM * 4 / 1e6  # [K, L, B, dim]
        out["dense"][f"K{K}"] = rec
        print(f"dense  K={K:>8d}  per-round {rec['per_round_ms']:8.2f} ms"
              f"  gather {rec['round_gather_mb']:8.2f} MB/round")

    # --- sparse sweep: one phase-B compile for every K ----------------------
    traces_before = sparse_mod.TRAIN_TRACE_COUNT
    for K in Ks:
        store = build_store(K)
        cell = CellConfig(num_clients=K)
        cfg = SimConfig(**base, participation="sparse",
                        participant_bucket=bucket)
        runner = make_sparse_runner(mlp_loss, mlp_accuracy, store, te,
                                    RandomScheme(p_bar=E / K, num_clients=K),
                                    cell, cfg)
        rec = _timed_runs(runner, params, gains(K, T), T)
        rec["store_mb"] = store.nbytes / 1e6
        rec["round_gather_mb"] = bucket * 2 * 4 * DIM * 4 / 1e6
        out["sparse"][f"K{K}"] = rec
        print(f"sparse K={K:>8d}  per-round {rec['per_round_ms']:8.2f} ms"
              f"  gather {rec['round_gather_mb']:8.2f} MB/round")
    out["phase_b_traces_for_sweep"] = (sparse_mod.TRAIN_TRACE_COUNT
                                       - traces_before)

    # --- the acceptance ratio ----------------------------------------------
    K_target = 2048 if quick else 10 ** 5
    ratio = (out["sparse"][f"K{K_target}"]["per_round_ms"]
             / out["dense"][f"K{K_dense}"]["per_round_ms"])
    out["headline"] = {
        "sparse_K": K_target, "dense_K": K_dense,
        "sparse_vs_dense_per_round_ratio": ratio,
        "within_2x": ratio <= 2.0,
    }
    print(f"sparse K={K_target} vs dense K={K_dense}: {ratio:.2f}x "
          f"({'OK' if ratio <= 2.0 else 'OVER'} the 2x bound); "
          f"phase-B traces for the whole sweep: "
          f"{out['phase_b_traces_for_sweep']}")
    return out


def _write(payload, out_path):
    write_bench(out_path, payload)


def main_quick():
    """Entry point for the aggregated ``benchmarks.run`` harness."""
    payload = {"quick": True, **bench(True)}
    _write(payload, "BENCH_sparse.json")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--out", default="BENCH_sparse.json")
    args = ap.parse_args()
    payload = {"quick": args.quick, **bench(args.quick)}
    _write(payload, args.out)


if __name__ == "__main__":
    main()
