"""Serving-path benchmark: the async aggregation front door under load.

Emulates a ≥10³-client population (``repro.serve.loadgen``) hammering a
live :class:`~repro.serve.AggregationServer` on CPU and records the
numbers the subsystem exists to deliver:

* sustained **uploads/s** (admitted-and-aggregated, not merely enqueued),
* **admission latency** percentiles (submit → aggregated, the
  ``flush_interval_s`` bound in action),
* **micro-batch occupancy** (how full the pow2 buckets run),
* the server-side telemetry counters/spans (PR-9 ``repro.obs.telemetry``),

then **asserts the replay-parity contract** on the very session it
measured — the decision log re-run offline through the scan engine must
reproduce the ledgers bit-exactly and the served model to golden
tolerance.  A parity violation exits nonzero: this benchmark doubles as
the serving smoke gate in CI (``serve-smoke``).

Two load modes: ``throughput`` (clients always transmit — the ingest
ceiling) and ``paper`` (clients gate on the served ``p_{k,t}`` — the
probabilistic-participation regime the paper models).

Writes ``BENCH_serve.json`` (repro-bench/v1).

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import ProblemSpec, online_policy
from repro.obs.telemetry import get_telemetry
from repro.serve import (AggregationServer, LoadGenConfig, ServeConfig,
                         run_loadgen, toy_world, verify_replay)

from .common import write_bench


def _session(K: int, uploads: int, workers: int, respect_probs: bool,
             seed: int = 0) -> dict:
    params, store, loss_fn, acc_fn = toy_world(K, dim=16, classes=10,
                                               n_per=8, seed=seed)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(seed), cell)
    gains = channel_gains(jax.random.PRNGKey(seed + 1), pos, 64)
    pol = online_policy(ProblemSpec(cell=cell, rho=0.05, num_rounds=64))
    cfg = ServeConfig(num_clients=K, queue_capacity=max(256, workers * 8),
                      max_batch=64, min_bucket=8, flush_interval_s=0.002,
                      policy_refresh_min_interval_s=2.0, seed=seed)
    server = AggregationServer(params, cfg, policy_fn=pol, gains=gains,
                               cell=cell, start=True)
    # warmup burst: compiles the client step + every bucket shape of the
    # jitted aggregation, then zeroes the measurement windows — the
    # reported numbers are steady state.  The decision log still covers
    # the warmup, so replay parity is asserted over the full session.
    warm = LoadGenConfig(uploads=max(cfg.max_batch * 2, 128),
                         workers=workers, seed=seed + 100,
                         respect_probs=False, timeout_s=300.0)
    run_loadgen(server, store, loss_fn, warm)
    server.reset_stats()
    lg = LoadGenConfig(uploads=uploads, workers=workers, seed=seed,
                       rate_sigma=1.0, respect_probs=respect_probs,
                       timeout_s=300.0)
    report = run_loadgen(server, store, loss_fn, lg)
    server.close(drain=True)
    parity = verify_replay(server, store, params, loss_fn, acc_fn)
    report["replay"] = parity
    report["uploads_per_second"] = float(report["uploads_per_second"])
    return report


def _flush_ceiling(K: int, reps: int = 20) -> dict:
    """Server-side aggregation capacity, no client emulation in the way:
    fill a full ``max_batch`` of pending updates and time warm flushes.
    This is what the data plane can absorb; the loadgen modes below are
    end-to-end numbers limited by the emulated clients sharing the box."""
    import time as _time

    import jax.numpy as jnp

    params, _, _, _ = toy_world(K, dim=16, classes=10, n_per=8, seed=0)
    cfg = ServeConfig(num_clients=K, queue_capacity=256, max_batch=64,
                      min_bucket=8, seed=0)
    server = AggregationServer(params, cfg, start=False)
    d = jax.tree_util.tree_map(jnp.zeros_like, params)

    def fill():
        for k in range(cfg.max_batch):
            server.submit(k, d, server.version)

    fill()
    server.flush()                     # compile the bucket
    times = []
    for _ in range(reps):
        fill()
        t0 = _time.perf_counter()
        server.flush()
        times.append(_time.perf_counter() - t0)
    server.close()
    best = min(times)
    return {"max_batch": cfg.max_batch, "flush_ms": best * 1e3,
            "uploads_per_second_ceiling": cfg.max_batch / best}


def bench(quick: bool) -> dict:
    K = 1000 if quick else 4000
    uploads = 500 if quick else 2000
    workers = 4 if quick else 8
    tel = get_telemetry()
    tel.reset()

    out: dict = {"clients": K, "modes": {}}
    out["flush_ceiling"] = _flush_ceiling(K)
    print(f"[bench_serve] flush ceiling: "
          f"{out['flush_ceiling']['uploads_per_second_ceiling']:.0f} "
          f"uploads/s ({out['flush_ceiling']['flush_ms']:.2f} ms per "
          f"{out['flush_ceiling']['max_batch']}-batch)")
    for mode, respect in (("throughput", False), ("paper", True)):
        print(f"[bench_serve] {mode}: K={K}, target={uploads} uploads")
        rep = _session(K, uploads, workers, respect_probs=respect)
        print(f"[bench_serve]   {rep['uploads_per_second']:.1f} uploads/s, "
              f"{rep['batches']} batches, "
              f"admit p95 {rep['admit_ms'].get('p95', 0):.2f} ms, "
              f"replay max|err| {rep['replay']['model_max_abs_err']:.2e}")
        out["modes"][mode] = rep

    flush = tel.span_stats("serve.flush")
    policy = tel.span_stats("serve.policy_refresh")
    out["telemetry"] = {
        "counters": {k: v for k, v in tel.snapshot().items()
                     if k.startswith("serve.")},
        "flush_span": flush, "policy_refresh_span": policy,
    }
    out["parity_ok"] = all(m["replay"]["ok"] for m in out["modes"].values())
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: K=1000, 300 uploads per mode")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    payload = bench(args.quick)
    write_bench(args.out, payload)
    if not payload["parity_ok"]:       # replay divergence = hard failure
        print("[bench_serve] REPLAY PARITY VIOLATED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
