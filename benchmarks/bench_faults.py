"""Fault-injection benchmark: guard overhead + the degradation curve.

Three measurements on the same tiny world:

* ``clean``     — no faults, no guards (the pre-robustness fast path).
* ``unguarded`` — full fault cocktail (Markov churn, crashes, lossy uplinks
  with retry, NaN corruption), server takes updates at face value.
* ``guarded``   — same faults behind the defensive aggregation stack
  (quarantine + norm clip + staleness down-weighting).

The headline acceptance: the guarded per-round wall-clock stays within 10%
of the unguarded faulty run — the defenses are mask arithmetic, not a second
pass.  A :func:`repro.fl.faults.run_fault_matrix` sweep then records the
accuracy/energy degradation curve over fault severity and asserts the
guarded lane stays finite at every rate while the unguarded one goes
non-finite once corruption bites.

Writes ``BENCH_faults.json`` (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.bench_faults [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import RandomScheme
from repro.data import make_mnist_like, shard_noniid
from repro.data.synthetic import Dataset
from repro.fl import (FaultConfig, GuardConfig, SimConfig, make_runner,
                      run_fault_matrix)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

from .common import write_bench

DIM = 64

FAULTS = FaultConfig(p_fail=0.1, p_recover=0.5, diurnal_amp=0.5,
                     p_crash=0.05, p_loss=0.2, max_retries=1, backoff=2.0,
                     p_corrupt=0.2, corrupt_mode="nan")
GUARDS = GuardConfig(quarantine=True, clip_norm=10.0, staleness_power=0.5)


def tiny_world(K: int, T: int):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=2000, n_test=400)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=2)
    clients = [Dataset(c.x[:, :DIM], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :DIM], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, T).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(DIM, 32, 10))
    return clients, te, cell, h, params


def _timed_runs(runner, params, h, T: int):
    t0 = time.perf_counter()
    res = runner(params, h)
    jax.block_until_ready(res.state.global_params)
    cold_s = time.perf_counter() - t0
    warm = []
    for _ in range(3):
        t1 = time.perf_counter()
        out = runner(params, h)
        jax.block_until_ready(out.state.global_params)
        warm.append(time.perf_counter() - t1)
    warm_s = min(warm)
    leaves = jax.tree_util.tree_leaves(res.state.global_params)
    return {
        "cold_s": cold_s,
        "warm_s": warm_s,
        "per_round_ms": warm_s / T * 1e3,
        "final_acc": float(res.test_acc[-1]),
        "final_params_finite": bool(all(np.isfinite(np.asarray(p)).all()
                                        for p in leaves)),
    }


def bench(quick: bool) -> dict:
    K = 5
    T = 12 if quick else 60
    rates = [0.0, 0.5, 1.0] if quick else [0.0, 0.25, 0.5, 0.75, 1.0]
    clients, te, cell, h, params = tiny_world(K, T)
    policy = RandomScheme(p_bar=0.5, num_clients=K)
    base = dict(rounds=T, local_iters=2, batch_size=16, eval_every=T,
                eval_batch=200, data_path="device")
    out = {"config": {"K": K, "T": T, "rates": rates, "dim": DIM,
                      "backend": jax.default_backend()}}

    # --- guard overhead: clean vs faulty-unguarded vs faulty-guarded --------
    for name, cfg in [
        ("clean", SimConfig(**base)),
        ("unguarded", SimConfig(**base, faults=FAULTS)),
        ("guarded", SimConfig(**base, faults=FAULTS, guards=GUARDS)),
    ]:
        runner = make_runner(mlp_loss, mlp_accuracy, clients, te, policy,
                             cell, cfg)
        rec = _timed_runs(runner, params, h, T)
        out[name] = rec
        print(f"{name:>10s}  per-round {rec['per_round_ms']:8.3f} ms"
              f"  final acc {rec['final_acc']:.3f}"
              f"  finite={rec['final_params_finite']}")

    ratio = out["guarded"]["per_round_ms"] / out["unguarded"]["per_round_ms"]
    fault_cost = (out["unguarded"]["per_round_ms"]
                  / out["clean"]["per_round_ms"])
    out["headline"] = {
        "guard_overhead_ratio": ratio,
        "within_10pct": ratio <= 1.10,
        "fault_process_ratio_vs_clean": fault_cost,
    }
    print(f"guard overhead: {ratio:.3f}x vs unguarded "
          f"({'OK' if ratio <= 1.10 else 'OVER'} the 1.10x bound); "
          f"fault processes cost {fault_cost:.2f}x vs clean")

    # --- degradation curve: accuracy/energy vs fault severity ---------------
    cfg = SimConfig(**{**base, "eval_every": max(T // 4, 1)}, faults=FAULTS)
    mat = run_fault_matrix(params, mlp_loss, mlp_accuracy, clients, te,
                           policy, h, cell, cfg, rates, guard=GUARDS)
    out["degradation"] = {
        "rates": list(mat.rates),
        "eval_rounds": mat.eval_rounds.tolist(),
        "acc_guarded": np.asarray(mat.acc["guarded"]).tolist(),
        "acc_unguarded": np.asarray(mat.acc["unguarded"]).tolist(),
        "energy_guarded_j": np.asarray(
            mat.energy["guarded"]).sum(-1).tolist(),
        "energy_unguarded_j": np.asarray(
            mat.energy["unguarded"]).sum(-1).tolist(),
        "delivered_mass": np.asarray(
            mat.delivered["guarded"]).sum((-1, -2)).tolist(),
        "finite_guarded": np.asarray(mat.finite_final["guarded"]).tolist(),
        "finite_unguarded": np.asarray(
            mat.finite_final["unguarded"]).tolist(),
    }
    finite_g = np.asarray(mat.finite_final["guarded"])
    out["headline"]["guarded_finite_all_rates"] = bool(finite_g.all())
    for r, ag, au, fg, fu in zip(mat.rates,
                                 np.asarray(mat.acc["guarded"])[:, -1],
                                 np.asarray(mat.acc["unguarded"])[:, -1],
                                 finite_g,
                                 np.asarray(mat.finite_final["unguarded"])):
        print(f"rate {r:4.2f}  acc guarded {ag:.3f} (finite={bool(fg)})"
              f"  unguarded {au:.3f} (finite={bool(fu)})")
    assert finite_g.all(), "guarded lane went non-finite"
    return out


def _write(payload, out_path):
    write_bench(out_path, payload)


def main_quick():
    """Entry point for the aggregated ``benchmarks.run`` harness."""
    payload = {"quick": True, **bench(True)}
    _write(payload, "BENCH_faults.json")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    payload = {"quick": args.quick, **bench(args.quick)}
    _write(payload, args.out)


if __name__ == "__main__":
    main()
