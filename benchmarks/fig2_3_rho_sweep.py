"""Paper Fig. 2 & 3: accuracy / total energy vs the tradeoff coefficient ρ.

Claim under test: as ρ grows from ~0.01 to ~0.1 both participation and
accuracy rise (convergence-focused); beyond that, accuracy saturates or
degrades under non-IID drift while energy keeps climbing.
"""
from __future__ import annotations

import numpy as np

from repro.core import ProblemSpec

from .common import build_world, row, run_policy, save_artifact
from repro.core.selection import ProposedOnline


def main() -> list[dict]:
    # d=2 (strong heterogeneity) exposes the high-ρ drift the paper reports
    world = build_world(d=2, rounds=24)
    rhos = (0.01, 0.03, 0.1, 0.3, 0.9)
    out = []
    for rho in rhos:
        spec = ProblemSpec(cell=world.cell, rho=rho, lam=0.01,
                           num_rounds=world.rounds)
        res, secs = run_policy(world, ProposedOnline(spec))
        rec = {"rho": rho,
               "final_acc": float(res.test_acc[-1]),
               "total_energy_j": float(res.energy_per_client.sum()),
               "avg_participants": float(res.participation.sum()
                                         / world.rounds)}
        out.append(rec)
        row(f"fig2_rho_{rho}", secs / world.rounds * 1e6,
            f"acc={rec['final_acc']:.3f};energy_j={rec['total_energy_j']:.2f};"
            f"avg_k={rec['avg_participants']:.2f}")
    save_artifact("fig2_3_rho_sweep", {"rows": out})
    return out


if __name__ == "__main__":
    main()
