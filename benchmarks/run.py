"""Benchmark harness — one module per paper table/figure plus kernels,
Algorithm-1 microbenchmarks and the roofline readout.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) and writes
JSON artifacts to ``artifacts/bench/``.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig6       # substring filter
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (bench_algorithm1, bench_data, bench_engine, bench_faults,
               bench_kernels, bench_staleness, fig2_3_rho_sweep,
               fig4_5_energy, fig6_7_schemes, fig8_9_scenarios)

SUITES = [
    ("bench_algorithm1", bench_algorithm1.main),
    ("bench_data", lambda: bench_data.main_quick()),
    ("bench_engine", lambda: bench_engine.main_quick()),
    ("bench_faults", lambda: bench_faults.main_quick()),
    ("bench_kernels", bench_kernels.main),
    ("bench_staleness", bench_staleness.main),
    ("fig2_3_rho_sweep", fig2_3_rho_sweep.main),
    ("fig4_5_energy", fig4_5_energy.main),
    ("fig6_7_schemes", lambda: fig6_7_schemes.main(["--quick"])),
    ("fig8_9_scenarios", fig8_9_scenarios.main),
]


def main() -> None:
    filt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failures = []
    for name, fn in SUITES:
        if filt and filt not in name:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"{name}_total,{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"{name}_total,0,FAILED:{type(e).__name__}")
    # roofline readout is optional — requires dry-run artifacts
    try:
        from . import roofline
        rows = roofline.main()
        print(f"roofline_total,0,rows={len(rows)}")
    except Exception as e:  # noqa: BLE001
        print(f"roofline_total,0,skipped:{type(e).__name__}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
