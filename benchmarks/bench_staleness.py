"""Beyond-paper ablation: the Δ_k bound (each client must transmit at least
once within Δ_k rounds — paper §II-A) enforced vs pure-Bernoulli selection.

Theory (Lemma 1): bounding the max interval tightens the convergence bound;
with probabilistic selection alone, Δ_k is only bounded in expectation.
"""
from __future__ import annotations

import numpy as np

from repro.core import ProblemSpec
from repro.core.selection import ProposedOnline

from .common import build_world, row, run_policy, save_artifact


def main() -> dict:
    world = build_world(rounds=20, d=2)
    spec = ProblemSpec(cell=world.cell, rho=0.03, num_rounds=world.rounds)
    out = {}
    for name, stale, aging in (("pure_bernoulli", None, False),
                               ("delta_4", 4, False), ("delta_8", 8, False),
                               ("delta_8_soft_aging", 8, True)):
        res, secs = run_policy(world, ProposedOnline(spec),
                               max_staleness=stale, aging=aging)
        gaps = []
        for k in range(world.cell.num_clients):
            tx = np.where(res.participation[:, k] > 0)[0]
            gaps.append(int(np.diff(tx).max()) if len(tx) > 1
                        else world.rounds)
        out[name] = {"final_acc": float(res.test_acc[-1]),
                     "total_energy_j": float(res.energy_per_client.sum()),
                     "max_gap": int(max(gaps))}
        row(f"staleness_{name}", secs / world.rounds * 1e6,
            f"acc={out[name]['final_acc']:.3f};"
            f"energy_j={out[name]['total_energy_j']:.2f};"
            f"max_gap={out[name]['max_gap']}")
    save_artifact("bench_staleness", out)
    return out


if __name__ == "__main__":
    main()
