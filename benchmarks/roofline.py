"""Roofline analysis (deliverable g): three terms per (arch × shape) from the
dry-run artifacts, TPU v5e constants.

  compute    = FLOPs_dev / peak_FLOP/s        (197 TF bf16 / chip)
  memory     = bytes_dev / HBM_bw             (819 GB/s / chip)
  collective = coll_bytes_dev / link_bw       (~50 GB/s / ICI link)

FLOPs/bytes come from the 1-/2-super-block unrolled *cost probes* (exact —
XLA counts scan bodies once, see models/costmode.py); collective bytes are
parsed per-device from post-SPMD HLO.  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (serve); the ratio MODEL/HLO flags remat/redundancy waste.
sLSTM keeps a true time recurrence inside the probes, corrected analytically
below (xlstm only).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs                                    # noqa: E402
from repro.configs.shapes import SHAPES                      # noqa: E402
from repro.fl.distributed import param_count                 # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW,               # noqa: E402
                               PEAK_FLOPS_BF16)

try:                                                         # noqa: E402
    from .common import write_bench
except ImportError:                                          # plain-script run
    from common import write_bench

ART = os.environ.get("REPRO_DRYRUN_ART", "artifacts/dryrun")


def active_param_count(cfg) -> int:
    """Params touched per token: MoE counts top_k of num_experts."""
    import dataclasses
    if cfg.moe is None:
        return param_count(cfg)
    full = param_count(cfg)
    m = cfg.moe
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if cfg.ffn_kind(i) == "moe")
    per_layer_expert = 3 * cfg.d_model * m.d_ff_expert
    return int(full - n_moe_layers * (m.num_experts - m.top_k)
               * per_layer_expert)


def model_flops(cfg, shape) -> float:
    n_act = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per sequence


def slstm_correction(cfg, shape, devices: int) -> float:
    """Per-device flops the probes miss inside the sLSTM time scan."""
    if "slstm" not in cfg.mixer_pattern or shape.kind == "decode":
        return 0.0
    d = cfg.d_model
    hd = d // cfg.n_heads
    per_token = 10 * d * d + 8 * d * hd
    n_slstm = sum(1 for i in range(cfg.n_layers)
                  if cfg.mixer_pattern[i % len(cfg.mixer_pattern)] == "slstm")
    tokens = shape.global_batch * shape.seq_len
    factor = 3.0 if shape.kind == "train" else 1.0
    missed = factor * n_slstm * per_token * tokens * (shape.seq_len - 1) \
        / shape.seq_len
    return missed / devices


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "cost_probe" not in rec:
        return None
    cfg = configs.get(rec["arch"], SHAPES[rec["shape"]])
    shape = SHAPES[rec["shape"]]
    dev = rec["devices"]
    tot = rec["cost_probe"]["total"]
    f_dev = tot["flops"] + slstm_correction(cfg, shape, dev)
    b_dev = tot["bytes"]
    # differencing can go slightly negative when XLA optimizes the 2-block
    # probe more aggressively than the 1-block one — clamp to the 1-block
    # measurement as a floor
    c_dev = max(tot["collective_bytes"],
                rec["cost_probe"]["m1"]["collectives"]["total_bytes"])
    t_compute = f_dev / PEAK_FLOPS_BF16
    t_memory = b_dev / HBM_BW
    t_coll = c_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    ratio = mf / max(f_dev * dev, 1.0)
    suggestion = {
        "compute": "reduce recompute (remat policy) / use causal-aware "
                   "kernels to halve masked attention flops",
        "memory": "larger fused blocks + bf16 intermediates to cut HBM "
                  "traffic; keep activations model-sharded through the scan",
        "collective": "reshard to cut the dominant collective (vocab-parallel "
                      "loss for logits all-reduce; overlap AR with compute)",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec.get("mode", "-"),
        "flops_per_dev": f_dev, "bytes_per_dev": b_dev,
        "coll_bytes_per_dev": c_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "useful_ratio": ratio,
        "suggestion": suggestion,
        "hbm_per_dev_gb": (rec["memory_analysis"]["argument_size_in_bytes"]
                           + rec["memory_analysis"]["temp_size_in_bytes"])
        / 1e9,
    }


def main() -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*_16x16.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = analyze(rec)
        if r:
            rows.append(r)
            print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                  f"compute={r['t_compute_s']:.3e}s;"
                  f"memory={r['t_memory_s']:.3e}s;"
                  f"collective={r['t_collective_s']:.3e}s;"
                  f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")
    os.makedirs("artifacts", exist_ok=True)
    write_bench("artifacts/roofline.json", {"rows": rows})

    # markdown table for EXPERIMENTS.md
    lines = ["| arch | shape | mode | compute s | memory s | collective s |"
             " dominant | MODEL/HLO | HBM GB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['hbm_per_dev_gb']:.1f} |")
    with open("artifacts/roofline_table.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    return rows


if __name__ == "__main__":
    main()
