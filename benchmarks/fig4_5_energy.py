"""Paper Fig. 4 & 5: total energy vs average participants per round (Fig. 4)
and vs the number of clients K at fixed participation 0.1 (Fig. 5).

Claim under test: the proposed joint optimization spends markedly less
energy than random/greedy/age at every operating point.
"""
from __future__ import annotations

import numpy as np

from repro.core import ProblemSpec
from repro.core.channel import rate_nats
from repro.core.selection import (AgeBasedScheme, GreedyScheme,
                                  ProposedOnline, RandomScheme,
                                  average_participants, realize)

from .common import build_world, row, save_artifact

import jax
import jax.numpy as jnp


def expected_energy(world, policy, rounds):
    """Expected per-round energy Σ p·P·S/R (eq. 5) summed over rounds —
    energy-only comparison (no model training needed)."""
    c = world.cell
    tot = 0.0
    per_client = np.zeros(c.num_clients)
    for t in range(rounds):
        d = policy.decide(t, world.h[:, t])
        R = rate_nats(d.w, world.h[:, t], c.tx_power_w, c.bandwidth_hz,
                      c.noise_w_per_hz)
        e = np.asarray(d.probs * c.tx_power_w * c.model_size_nats
                       / jnp.maximum(R, 1e-30))
        e = np.where(np.asarray(d.probs) > 0, e, 0.0)
        per_client += e
        tot += e.sum()
    return tot, per_client


def main() -> dict:
    out = {"fig4": [], "fig5": []}

    # --- Fig. 4: energy vs avg participants (vary rho) ----------------------
    world = build_world(rounds=30)
    for rho in (0.01, 0.05, 0.15, 0.4):
        spec = ProblemSpec(cell=world.cell, rho=rho, num_rounds=world.rounds)
        prop = ProposedOnline(spec)
        avg = average_participants(prop, world.h)
        k = max(1, round(avg))
        K = world.cell.num_clients
        schemes = [prop, RandomScheme(min(avg / K, 1.0), K),
                   GreedyScheme(k, K), AgeBasedScheme(k, K)]
        rec = {"avg_participants": avg}
        for s in schemes:
            e, _ = expected_energy(world, s, world.rounds)
            rec[s.name] = float(e)
        out["fig4"].append(rec)
        row(f"fig4_avgk_{avg:.2f}", 0.0,
            ";".join(f"{s.name}={rec[s.name]:.2f}J" for s in schemes))

    # --- Fig. 5: energy vs number of clients at participation 0.1 -----------
    for K in (10, 20, 30):
        world = build_world(K=K, rounds=30, d=5 if K * 5 % 10 == 0 else 5)
        spec = ProblemSpec(cell=world.cell, rho=0.05, num_rounds=world.rounds)
        prop = ProposedOnline(spec)
        k = max(1, round(0.1 * K))
        schemes = [prop, RandomScheme(0.1, K), GreedyScheme(k, K),
                   AgeBasedScheme(k, K)]
        rec = {"K": K}
        for s in schemes:
            e, _ = expected_energy(world, s, world.rounds)
            rec[s.name] = float(e)
        out["fig5"].append(rec)
        row(f"fig5_K_{K}", 0.0,
            ";".join(f"{s.name}={rec[s.name]:.2f}J" for s in schemes))

    save_artifact("fig4_5_energy", out)
    return out


if __name__ == "__main__":
    main()
