"""Kernel microbenchmarks: interpret-mode Pallas vs jnp oracle (correctness +
CPU latency; TPU is the target, so derived figures are the VMEM working-set
and arithmetic-intensity numbers used in DESIGN.md §7)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.fl_aggregate import BLOCK_R, LANE, fl_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.selective_scan import selective_scan

from .common import row, save_artifact


def _time(f, n=3):
    f()  # compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f())
    return (time.time() - t0) / n


def main() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)

    # fl_aggregate: K=16 clients, 1M params
    K, M = 16, 1_000_000
    g = jax.random.normal(key, (M,), jnp.float32)
    d = jax.random.normal(key, (K, M), jnp.float32)
    m = (jax.random.uniform(key, (K,)) < 0.5).astype(jnp.float32)
    t_ref = _time(lambda: ref.fl_aggregate_ref(g, d, m))
    err = float(jnp.abs(fl_aggregate(g, d, m, interpret=True)
                        - ref.fl_aggregate_ref(g, d, m)).max())
    hbm_naive = (K * M * 4) * 2 + M * 8          # unfused: read δ, write temp, rw global
    hbm_fused = K * M * 4 + M * 8                # fused single pass
    out["fl_aggregate"] = {"ref_us": t_ref * 1e6, "maxerr": err,
                           "hbm_bytes_fused": hbm_fused,
                           "hbm_bytes_naive": hbm_naive,
                           "vmem_block_kb": K * BLOCK_R * LANE * 4 / 1024}
    row("kernel_fl_aggregate", t_ref * 1e6,
        f"maxerr={err:.1e};hbm_saving={hbm_naive/hbm_fused:.2f}x")

    # flash attention: 1×512×8h(2kv)×128
    q = jax.random.normal(key, (1, 512, 8, 128), jnp.bfloat16)
    k = jax.random.normal(key, (1, 512, 2, 128), jnp.bfloat16)
    v = jax.random.normal(key, (1, 512, 2, 128), jnp.bfloat16)
    t_ref = _time(lambda: ref.flash_attention_ref(q, k, v))
    errf = float(jnp.abs(
        flash_attention(q, k, v, interpret=True).astype(jnp.float32)
        - ref.flash_attention_ref(q, k, v).astype(jnp.float32)).max())
    out["flash_attention"] = {"ref_us": t_ref * 1e6, "maxerr": errf,
                              "vmem_block_kb": (128 * 128 * 4 * 3
                                                + 2 * 128 * 128 * 4) / 1024}
    row("kernel_flash_attention", t_ref * 1e6, f"maxerr={errf:.1e}")

    # selective scan: 1×512×512, N=16
    B, S, dd, N = 1, 512, 512, 16
    ks = jax.random.split(key, 6)
    xc = jax.random.normal(ks[0], (B, S, dd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, dd)) - 1)
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (dd, N)) * 0.3)
    Dv = jax.random.normal(ks[5], (dd,))
    t_ref = _time(lambda: ref.selective_scan_ref(xc, dt, Bm, Cm, A, Dv))
    errs = float(jnp.abs(
        selective_scan(xc, dt, Bm, Cm, A, Dv, interpret=True)
        - ref.selective_scan_ref(xc, dt, Bm, Cm, A, Dv)).max())
    out["selective_scan"] = {"ref_us": t_ref * 1e6, "maxerr": errs,
                             "vmem_state_kb": 256 * N * 4 / 1024}
    row("kernel_selective_scan", t_ref * 1e6, f"maxerr={errs:.1e}")

    save_artifact("bench_kernels", out)
    return out


if __name__ == "__main__":
    main()
