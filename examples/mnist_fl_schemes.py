"""End-to-end driver (deliverable b): train the paper's MNIST-MLP federated
system for a few hundred rounds under all four schemes and print the
accuracy-per-Joule comparison (paper Fig. 6).

    PYTHONPATH=src python examples/mnist_fl_schemes.py [--rounds 200]
"""
import argparse

import jax
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (AgeBasedScheme, GreedyScheme,
                                  ProposedOnline, RandomScheme,
                                  average_participants)
from repro.data import make_mnist_like, shard_noniid
from repro.fl import SimConfig, run_simulation
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--noniid-d", type=int, default=5)
    ap.add_argument("--train-examples", type=int, default=20000)
    args = ap.parse_args()

    K = args.clients
    tr, te = make_mnist_like(jax.random.PRNGKey(0),
                             n_train=args.train_examples, n_test=2000)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=args.noniid_d)
    cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=args.rounds)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, args.rounds).T
    params = init_mlp(jax.random.PRNGKey(4))
    cfg = SimConfig(rounds=args.rounds, local_iters=5, batch_size=10,
                    eval_every=max(args.rounds // 20, 1))

    proposed = ProposedOnline(spec)
    avg = average_participants(proposed, h)
    k = max(1, round(avg))
    schemes = [proposed, RandomScheme(min(avg / K, 1.0), K),
               GreedyScheme(k, K), AgeBasedScheme(k, K)]
    print(f"matched participation: avg={avg:.2f} clients/round (k={k})")
    print(f"{'scheme':12s} {'final_acc':>9s} {'energy_J':>9s} "
          f"{'acc/J':>9s} {'gini':>6s}")
    for s in schemes:
        res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                             s, h, cell, cfg)
        e = res.energy_per_client
        gini = float(np.abs(e[:, None] - e[None, :]).sum()
                     / (2 * K * max(e.sum(), 1e-9)))
        print(f"{s.name:12s} {res.test_acc[-1]:9.3f} {e.sum():9.2f} "
              f"{res.test_acc[-1] / max(e.sum(), 1e-9):9.4f} {gini:6.3f}")


if __name__ == "__main__":
    main()
