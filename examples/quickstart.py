"""Quickstart: the paper's full pipeline in ~60 lines.

1. build a wireless cell (Table II),
2. solve the joint probabilistic-selection + bandwidth problem (Algorithm 1,
   online variant) for one round's channel state,
3. run a short asynchronous-FL training with the optimized policy and
   compare against the random baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import CellConfig, ProblemSpec, solve_online
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import ProposedOnline, RandomScheme
from repro.data import make_mnist_like, shard_noniid
from repro.fl import SimConfig, run_simulation
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

K, ROUNDS = 10, 12

# --- 1. wireless cell ---------------------------------------------------------
cell = CellConfig(num_clients=K)
spec = ProblemSpec(cell=cell, rho=0.05, lam=0.01, num_rounds=ROUNDS)
pos = sample_positions(jax.random.PRNGKey(2), cell)
h = channel_gains(jax.random.PRNGKey(3), pos, ROUNDS).T          # [K, T]

# --- 2. one-round joint optimization (P1', eqs. 31/46) ------------------------
res = solve_online(h[:, 0], spec)
print("selection probabilities p*:", np.asarray(res.p).round(3))
print("bandwidth ratios       w*:", np.asarray(res.w).round(3),
      "(sum=%.3f)" % float(res.w.sum()))
print("KKT residual: %.2e  (globally optimal by Thm 2 + Jong's algorithm)"
      % float(res.residual))

# --- 3. async FL: proposed vs random ------------------------------------------
train, test = make_mnist_like(jax.random.PRNGKey(0), n_train=4000, n_test=800)
clients = shard_noniid(jax.random.PRNGKey(1), train, K, d=5)      # non-IID
params = init_mlp(jax.random.PRNGKey(4))
cfg = SimConfig(rounds=ROUNDS, local_iters=5, batch_size=10, eval_every=4)

for policy in (ProposedOnline(spec), RandomScheme(p_bar=0.1, num_clients=K)):
    out = run_simulation(params, mlp_loss, mlp_accuracy, clients, test,
                         policy, h, cell, cfg)
    print(f"{policy.name:10s} final_acc={out.test_acc[-1]:.3f} "
          f"energy={out.energy_per_client.sum():.2f} J "
          f"(per-client max/min="
          f"{out.energy_per_client.max() / max(out.energy_per_client.min(), 1e-9):.1f})")
