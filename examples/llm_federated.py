"""FL-train a (reduced) assigned LLM architecture with probabilistic client
selection — the mega-arch integration path, runnable on CPU.

    PYTHONPATH=src python examples/llm_federated.py --arch qwen3-moe-30b-a3b
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import ProposedOnline, realize
from repro.data import Dataset, data_stream_key, from_client_datasets, make_token_stream
from repro.fl.distributed import fl_train_step_from_store, init_dist_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.names())
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    K, B, S = args.clients, 2, args.seq_len
    cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=args.rounds)
    pos = sample_positions(jax.random.PRNGKey(0), cell)
    h = channel_gains(jax.random.PRNGKey(1), pos, args.rounds).T
    policy = ProposedOnline(spec)

    # each client owns a fixed corpus shard (device-resident store); every
    # round samples its [K, B, S] batch on device from fold_in(data_key, t)
    # — no [T, K, B, S] host pre-stack, so the horizon is memory-free
    ds = make_token_stream(jax.random.PRNGKey(2), n_seqs=K * 4 * B,
                           vocab=cfg.vocab, seq_len=S)
    per_client = ds.x.reshape(K, 4 * B, S)
    store = from_client_datasets(
        [Dataset(per_client[k], jnp.zeros((4 * B,), jnp.int32), cfg.vocab)
         for k in range(K)])
    data_key = data_stream_key(2)
    state = init_dist_state(jax.random.PRNGKey(3), cfg, K)
    key = jax.random.PRNGKey(4)
    print(f"[llm-fl] {cfg.name}: K={K} clients, probabilistic selection")
    first = last = None
    for t in range(args.rounds):
        dec = policy.decide(t, h[:, t])
        key, sub = jax.random.split(key)
        mask = realize(sub, dec)
        state, m = fl_train_step_from_store(state, cfg, store, data_key,
                                            jnp.int32(t), mask, 0.05, B)
        loss = float(m["loss"])
        first = loss if first is None else first
        last = loss
        print(f"  round {t}: loss={loss:.4f} p*={jnp.round(dec.probs, 3)} "
              f"tx={int(m['participants'])}")
    print(f"[llm-fl] loss {first:.4f} → {last:.4f} "
          f"({'improved' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
