"""Batched serving example: prefill + greedy decode on a reduced assigned
architecture — exercises the same serve_step the decode dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batched.py --arch xlstm-125m
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
         "--reduced", "--batch", str(args.batch),
         "--new-tokens", str(args.new_tokens)]))
