"""Empirical Lemma-1 check: smaller enforced max-interval Δ ⇒ smaller
average squared gradient norm of the global iterates (the bound's
(Σ Δ_k²)/K term in action), at matched everything-else."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import RandomScheme
from repro.data import make_mnist_like, shard_noniid
from repro.fl import SimConfig, run_simulation
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss


def run_with_delta(delta, rounds=20):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=4000, n_test=400)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, 10, d=2)
    cell = CellConfig(num_clients=10)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4))
    cfg = SimConfig(rounds=rounds, local_iters=2, batch_size=10,
                    eval_every=1000, max_staleness=delta)
    # p̄ ≈ 0 ⇒ participation is (nearly) purely Δ-driven: Δ_k = delta exactly
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         RandomScheme(p_bar=0.001, num_clients=10), h, cell,
                         cfg)
    # average squared global-gradient norm over the trajectory endpoint
    gx, gy = tr.x[:2000], tr.y[:2000]
    g = jax.grad(mlp_loss)(res.state.global_params, gx, gy)
    return float(sum(jnp.sum(l ** 2) for l in jax.tree_util.tree_leaves(g)))


def test_smaller_delta_smaller_grad_norm():
    g2 = run_with_delta(2)
    g10 = run_with_delta(10)
    # Lemma 1: the Δ² term dominates the gap; tight Δ converges further
    assert g2 < g10, (g2, g10)
