"""Optimizers + checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import adam, momentum, sgd
from repro.optim.optim import apply_updates


def quad_loss(p):
    return jnp.sum((p["x"] - 3.0) ** 2) + jnp.sum((p["y"] + 1.0) ** 2)


def run_opt(opt, steps=200):
    params = {"x": jnp.zeros((4,)), "y": jnp.zeros((3,))}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(quad_loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return params


def test_sgd_converges():
    p = run_opt(sgd(0.1))
    assert np.allclose(np.asarray(p["x"]), 3.0, atol=1e-3)


def test_momentum_converges():
    p = run_opt(momentum(0.05))
    assert np.allclose(np.asarray(p["x"]), 3.0, atol=1e-2)


def test_adam_converges():
    p = run_opt(adam(0.1), steps=400)
    assert np.allclose(np.asarray(p["y"]), -1.0, atol=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": [jnp.ones((2,)), {"c": jnp.zeros((1,), jnp.int32)}]}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, metadata={"round": 7})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, meta = load_checkpoint(path, like)
    assert meta["round"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert np.allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch(tmp_path):
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, {"a": jnp.ones((2,))})
    import pytest
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jnp.ones((2,)), "b": jnp.ones((2,))})
