"""Boundary coverage for the participation-sizing helpers:
`average_participants` (expected transmitting mass) and
`participant_bucket` (static padded bucket sizing) at the edges the
sweeps never hit — zero expected mass, cap == floor, and K=1 worlds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selection import (average_participants, csma_policy,
                                  greedy_policy, participant_bucket,
                                  participants_from_mask, random_policy)


# ---------------------------------------------------------------------------
# participant_bucket
# ---------------------------------------------------------------------------


def test_bucket_expected_zero():
    # zero expected mass still yields a usable bucket: the mean clamps to 1,
    # headroom applies, and the floor/cap clamp wins
    b = participant_bucket(0.0, cap=1024)
    assert b >= 8 and (b & (b - 1)) == 0  # power of two, ≥ floor


def test_bucket_expected_zero_small_cap():
    # cap below the floor: the cap must win (a bucket can never exceed K)
    assert participant_bucket(0.0, cap=4) == 4
    assert participant_bucket(0.0, cap=1) == 1


def test_bucket_cap_equals_floor():
    assert participant_bucket(100.0, cap=8, floor=8) == 8
    assert participant_bucket(0.0, cap=8, floor=8) == 8


def test_bucket_k1():
    assert participant_bucket(1.0, cap=1) == 1
    assert participant_bucket(0.0, cap=1, floor=8) == 1


def test_bucket_monotone_in_expected():
    caps = [participant_bucket(e, cap=1 << 20) for e in
            [0.0, 1.0, 4.0, 16.0, 64.0, 256.0, 1024.0]]
    assert all(b <= a for b, a in zip(caps, caps[1:]))
    # headroom: bucket always covers the expected mass itself
    for e in [1.0, 10.0, 100.0, 5000.0]:
        assert participant_bucket(e, cap=1 << 20) >= e


def test_bucket_never_exceeds_cap():
    for e in [0.0, 3.0, 1e6]:
        for cap in [1, 2, 7, 64]:
            assert participant_bucket(e, cap=cap) <= cap


# ---------------------------------------------------------------------------
# average_participants
# ---------------------------------------------------------------------------


def _h(K, T, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).gamma(2.0, 0.5, size=(K, T)),
        jnp.float32)


def test_average_participants_zero_probability():
    K, T = 6, 5
    avg = average_participants(random_policy(0.0, K), _h(K, T))
    assert avg == 0.0


def test_average_participants_constant_policy_exact():
    K, T = 6, 5
    # Bernoulli(p̄) every round: expected mass is exactly p̄·K
    avg = average_participants(random_policy(0.3, K), _h(K, T))
    np.testing.assert_allclose(avg, 0.3 * K, rtol=1e-6)


def test_average_participants_topk_exact():
    K, T = 8, 6
    avg = average_participants(greedy_policy(3, K), _h(K, T))
    np.testing.assert_allclose(avg, 3.0, rtol=1e-6)


def test_average_participants_k1():
    # single-client world: every policy's expected mass is its probability
    T = 4
    avg = average_participants(random_policy(0.7, 1), _h(1, T, seed=2))
    np.testing.assert_allclose(avg, 0.7, rtol=1e-6)
    avg = average_participants(greedy_policy(1, 1), _h(1, T, seed=2))
    np.testing.assert_allclose(avg, 1.0, rtol=1e-6)
    avg = average_participants(csma_policy(1, 1), _h(1, T, seed=2))
    assert 0.0 <= avg <= 1.0 + 1e-6


def test_average_participants_bucket_roundtrip_k1():
    # the sizing pipeline end-to-end at K=1: mass → bucket → compaction
    K = 1
    avg = average_participants(random_policy(1.0, K), _h(K, 3, seed=1))
    bucket = participant_bucket(avg, cap=K)
    assert bucket == 1
    idx, valid, n_tx = participants_from_mask(jnp.ones((K,)), bucket)
    assert int(n_tx) == 1 and bool(valid[0]) and int(idx[0]) == 0


def test_participants_from_mask_empty_round():
    # expected=0 realized: an all-zero mask compacts to an all-padding row
    idx, valid, n_tx = participants_from_mask(jnp.zeros((5,)), 4)
    assert int(n_tx) == 0
    assert not np.asarray(valid).any()
    assert (np.asarray(idx) == 5).all()  # padded with K
