"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned architecture (≤2 super-blocks, d_model ≤ 512, ≤ 4 experts) runs one
forward + one FL train step on CPU; asserts output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.fl.distributed import fl_train_step, init_dist_state
from repro.models import transformer as T

ALL_ARCHS = configs.names()


def _check_reduced_bounds(cfg):
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 2 * len(configs.get(cfg.name.replace("-smoke", ""))
                                   .mixer_pattern)
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


def _batch(cfg, key, B, S):
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward(name):
    cfg = configs.get(name).reduced()
    _check_reduced_bounds(cfg)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux = T.forward(params, cfg, **{
        k: v for k, v in batch.items() if k in ("tokens", "embeds")})
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_fl_train_step(name):
    """One probabilistic-client-selection FL round over the reduced arch."""
    cfg = configs.get(name).reduced()
    key = jax.random.PRNGKey(0)
    K, B, S = 2, 2, 16
    state = init_dist_state(key, cfg, num_clients=K)
    batch = _batch(cfg, jax.random.PRNGKey(1), K * B, S)
    batch = jax.tree_util.tree_map(
        lambda x: x.reshape((K, B) + x.shape[1:]), batch)
    mask = jnp.array([1.0, 0.0])
    state2, metrics = fl_train_step(state, cfg, batch, mask, lr=0.01)
    assert np.isfinite(float(metrics["loss"]))
    # global model moved (client 0 transmitted)
    g0 = jax.tree_util.tree_leaves(state.global_params)[0]
    g1 = jax.tree_util.tree_leaves(state2.global_params)[0]
    assert float(jnp.abs(g1.astype(jnp.float32)
                         - g0.astype(jnp.float32)).max()) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_serve_step(name):
    """Reduced decode: one token against a small cache."""
    cfg = configs.get(name).reduced()
    if cfg.embeds_input:
        cfg = dataclasses.replace(cfg, embeds_input=False)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B = 2
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab)
    logits, caches = T.prefill(params, cfg, tokens=toks, capacity=16)
    logits, caches = T.decode_step(params, cfg, toks[:, :1], caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_exact_assigned_specs():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    for name, (L, d, H, KV, ff, V) in spec.items():
        cfg = configs.get(name)
        assert cfg.n_layers == L and cfg.d_model == d
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        assert cfg.vocab == V
        # d_ff: dense archs carry it in d_ff; fine-grained MoE in d_ff_expert
        assert cfg.d_ff == ff or (cfg.moe and cfg.moe.d_ff_expert == ff)
    moe_spec = {"jamba-1.5-large-398b": (16, 2),
                "moonshot-v1-16b-a3b": (64, 6),
                "qwen3-moe-30b-a3b": (128, 8),
                "llama4-maverick-400b-a17b": (128, 1)}
    for name, (E, k) in moe_spec.items():
        m = configs.get(name).moe
        assert m.num_experts == E and m.top_k == k
