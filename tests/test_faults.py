"""Fault-injection layer + defensive aggregation: process units, engine
bit-parity under faults (scan == legacy == sparse on the same salted
streams), guard effectiveness at high corruption, the participant-bucket
overflow spill/error paths, and the corruption-can't-pass-silently
properties."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import RandomScheme
from repro.data import make_mnist_like, shard_noniid
from repro.data.synthetic import Dataset
from repro.fl import (FaultConfig, GuardConfig, SimConfig, run_fault_matrix,
                      run_simulation, run_simulation_legacy)
from repro.fl.faults import (apply_faults, corrupt_deltas, init_fault_state,
                             markov_availability, scale_params,
                             uplink_process)
from repro.fl.sparse import make_sparse_runner
from repro.fl.state import (finite_rows, guard_weights, guarded_aggregate,
                            masked_aggregate, update_norms)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

DIM = 64


def tiny_world(K=5, rounds=8):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=1000, n_test=300)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=2)
    clients = [Dataset(c.x[:, :DIM], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :DIM], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(DIM, 24, 10))
    return clients, te, cell, h, params


FAULTS = FaultConfig(p_fail=0.2, p_recover=0.5, p_crash=0.1, p_loss=0.2,
                     max_retries=1, p_corrupt=0.25, corrupt_mode="nan")
GUARDS = GuardConfig(quarantine=True, clip_norm=10.0, staleness_power=0.5)


def leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def all_finite(tree):
    return all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(tree))


# --- process units -----------------------------------------------------------


def test_markov_availability_absorbing_extremes():
    key = jax.random.PRNGKey(0)
    avail = jnp.ones((16,), bool)
    # p_fail=1, p_recover=0: everyone goes down and stays down
    cfg = FaultConfig(p_fail=1.0, p_recover=0.0)
    fp = cfg.params()
    for t in range(3):
        avail, _ = markov_availability(jnp.int32(t), jax.random.fold_in(
            key, t), avail, fp, cfg)
    assert not bool(avail.any())
    # p_fail=0: everyone stays up
    cfg0 = FaultConfig(p_fail=0.0)
    avail = jnp.ones((16,), bool)
    avail, _ = markov_availability(jnp.int32(0), key, avail, cfg0.params(),
                                   cfg0)
    assert bool(avail.all())


def test_uplink_retry_energy_accounting():
    key = jax.random.PRNGKey(7)
    mask = jnp.ones((8,), jnp.float32)
    # lossless: first attempt lands, unit energy
    cfg = FaultConfig(p_loss=0.0, max_retries=3, backoff=2.0)
    ok, att, mult, _ = uplink_process(jnp.int32(0), key, mask, cfg.params(),
                                      cfg)
    assert bool(ok.all())
    np.testing.assert_array_equal(np.asarray(att), 1.0)
    np.testing.assert_array_equal(np.asarray(mult), 1.0)
    # total loss: every attempt spent, geometric energy, nothing lands
    cfg = FaultConfig(p_loss=1.0, max_retries=2, backoff=2.0)
    ok, att, mult, _ = uplink_process(jnp.int32(0), key, mask, cfg.params(),
                                      cfg)
    assert not bool(ok.any())
    np.testing.assert_array_equal(np.asarray(att), 3.0)
    np.testing.assert_array_equal(np.asarray(mult), 1.0 + 2.0 + 4.0)


def test_apply_faults_energy_only_for_uploaders():
    """Unavailable clients and crashed clients never reach the uplink — no
    energy; lost uploads still pay (with retry overhead)."""
    K = 6
    cfg = FaultConfig(p_fail=1.0, p_recover=0.0)   # all down after 1 step
    fp = cfg.params()
    out, _ = apply_faults(jnp.int32(0), jax.random.PRNGKey(0),
                          jnp.ones((K,), jnp.float32),
                          jnp.full((K,), 2.0, jnp.float32),
                          init_fault_state(K), fp, cfg)
    np.testing.assert_array_equal(np.asarray(out.e_round), 0.0)
    np.testing.assert_array_equal(np.asarray(out.delivered), 0.0)
    cfg = FaultConfig(p_loss=1.0, max_retries=1, backoff=3.0)
    out, _ = apply_faults(jnp.int32(0), jax.random.PRNGKey(0),
                          jnp.ones((K,), jnp.float32),
                          jnp.full((K,), 2.0, jnp.float32),
                          init_fault_state(K), cfg.params(), cfg)
    np.testing.assert_array_equal(np.asarray(out.delivered), 0.0)
    np.testing.assert_allclose(np.asarray(out.e_round), 2.0 * (1 + 3))


def test_corrupt_deltas_modes():
    d = {"w": jnp.ones((4, 3)), "b": jnp.ones((4,))}
    flag = jnp.array([True, False, True, False])
    for mode, check in [
            ("nan", lambda x: np.isnan(x).all()),
            ("inf", lambda x: np.isposinf(x).all()),
            ("scale", lambda x: (x == 100.0).all())]:
        cfg = FaultConfig(p_corrupt=1.0, corrupt_mode=mode)
        out = corrupt_deltas(d, flag, cfg.params(), cfg)
        w = np.asarray(out["w"])
        assert check(w[0]) and check(w[2])
        np.testing.assert_array_equal(w[1], 1.0)
    with pytest.raises(ValueError, match="corrupt_mode"):
        bad = FaultConfig(corrupt_mode="bitflip")
        corrupt_deltas(d, flag, bad.params(), bad)


def test_scale_params_clips_to_unit_interval():
    fp = FaultConfig(p_fail=0.4, p_loss=0.9).params()
    hot = scale_params(fp, 5.0)
    assert float(hot.p_fail) == 1.0 and float(hot.p_loss) == 1.0
    cold = scale_params(fp, 0.0)
    assert float(cold.p_fail) == 0.0 and float(cold.p_loss) == 0.0


# --- guard primitives --------------------------------------------------------


def test_guard_weights_quarantine_clip_staleness():
    deltas = {"w": jnp.array([[3.0, 4.0], [jnp.nan, 1.0], [30.0, 40.0]])}
    stale = jnp.array([0, 0, 4], jnp.float32)
    w, safe = guard_weights(deltas, stale, GuardConfig(
        quarantine=True, clip_norm=5.0, staleness_power=1.0))
    # row 0: ‖δ‖=5 → clip factor 1, staleness 0 → weight 1
    # row 1: non-finite → 0, and the row is zeroed so 0·δ' can't NaN
    # row 2: ‖δ‖=50 → clip 0.1, staleness (1+4)^-1 = 0.2 → 0.02
    np.testing.assert_allclose(np.asarray(w), [1.0, 0.0, 0.02], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(safe["w"][1]), 0.0)
    # hard cap drops the stale row outright
    w2, _ = guard_weights(deltas, stale, GuardConfig(
        quarantine=False, staleness_cap=2))
    np.testing.assert_array_equal(np.asarray(w2), [1.0, 1.0, 0.0])


def test_finite_rows_and_update_norms():
    d = {"a": jnp.array([[1.0, 2.0], [jnp.inf, 0.0]]),
         "b": jnp.array([[2.0], [3.0]])}
    np.testing.assert_array_equal(np.asarray(finite_rows(d)), [True, False])
    np.testing.assert_allclose(np.asarray(update_norms(d)), [3.0, 3.0])


def test_guarded_aggregate_disabled_is_bitwise_plain():
    g = {"w": jnp.arange(6.0)}
    d = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 6))}
    m = jnp.array([1.0, 0.0, 1.0, 1.0])
    stale = jnp.zeros((4,), jnp.int32)
    plain = masked_aggregate(g, d, m, 4, use_pallas=False)
    for guards in (None, GuardConfig(quarantine=False)):
        out = guarded_aggregate(g, d, m, 4, stale, guards, use_pallas=False)
        leaves_equal(out, plain)


def test_guarded_aggregate_rejects_poison_keeps_honest_mass():
    """Quarantine = reject-and-reweight: the output equals the plain
    aggregate over the honest subset."""
    g = {"w": jnp.zeros((5,))}
    honest = jax.random.normal(jax.random.PRNGKey(1), (3, 5))
    d = {"w": jnp.concatenate([honest, jnp.full((1, 5), jnp.nan)], axis=0)}
    m = jnp.ones((4,))
    out = guarded_aggregate(g, d, m, 4, jnp.zeros((4,), jnp.int32),
                            GuardConfig(quarantine=True), use_pallas=False)
    want = {"w": jnp.sum(honest, axis=0) / 4.0}
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want["w"]),
                               atol=1e-6)
    assert all_finite(out)


# --- engine integration: parity + effectiveness ------------------------------


def run_both(cfg, K=5, rounds=8):
    clients, te, cell, h, params = tiny_world(K=K, rounds=rounds)
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          RandomScheme(p_bar=0.5, num_clients=K), h, cell,
                          cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, RandomScheme(p_bar=0.5, num_clients=K),
                                   h, cell, cfg)
    return scan, legacy


def test_faulty_guarded_scan_equals_legacy():
    cfg = SimConfig(rounds=8, local_iters=2, batch_size=8, eval_every=4,
                    eval_batch=200, data_path="device", faults=FAULTS,
                    guards=GUARDS)
    scan, legacy = run_both(cfg)
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_array_equal(scan.delivered, legacy.delivered)
    np.testing.assert_array_equal(scan.corrupted, legacy.corrupted)
    np.testing.assert_allclose(scan.energy_per_client,
                               legacy.energy_per_client, rtol=1e-6)
    leaves_equal(scan.state.global_params, legacy.state.global_params)
    assert all_finite(scan.state.global_params)


def test_fault_streams_never_perturb_participation():
    """The salted fold_in fault streams are disjoint from the participation
    draw: the decision masks of a faulty run equal the clean run's."""
    base = dict(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200, data_path="device")
    clean, _ = run_both(SimConfig(**base))
    faulty, _ = run_both(SimConfig(**base, faults=FAULTS, guards=GUARDS))
    np.testing.assert_array_equal(clean.participation, faulty.participation)
    assert clean.delivered is None and faulty.delivered is not None


def test_guards_keep_model_finite_at_high_corruption():
    """Acceptance gate: ≥10 % corrupted updates, guarded engine stays
    finite; the same run unguarded does not."""
    clients, te, cell, h, params = tiny_world(rounds=10)
    faults = FaultConfig(p_corrupt=0.5, corrupt_mode="nan")
    base = dict(rounds=10, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200, data_path="device", faults=faults)
    pol = RandomScheme(p_bar=0.6, num_clients=5)
    guarded = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                             pol, h, cell, SimConfig(**base, guards=GUARDS))
    assert guarded.corrupted.sum() >= 1, "corruption never fired"
    assert all_finite(guarded.state.global_params)
    assert np.isfinite(guarded.test_loss).all()
    unguarded = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                               pol, h, cell, SimConfig(**base))
    assert not all_finite(unguarded.state.global_params)


def test_scaled_norm_attack_bounded_by_clip():
    """The finite scaled-norm attack slips past quarantine but norm clipping
    bounds its influence: the guarded model stays close to clean scale."""
    clients, te, cell, h, params = tiny_world(rounds=8)
    faults = FaultConfig(p_corrupt=0.4, corrupt_mode="scale",
                         corrupt_scale=1e4)
    pol = RandomScheme(p_bar=0.6, num_clients=5)
    base = dict(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200, data_path="device", faults=faults)
    guarded = run_simulation(
        params, mlp_loss, mlp_accuracy, clients, te, pol, h, cell,
        SimConfig(**base, guards=GuardConfig(quarantine=False,
                                             clip_norm=1.0)))
    unguarded = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                               pol, h, cell, SimConfig(**base))
    norm_g = update_norms(jax.tree_util.tree_map(
        lambda g: g[None], guarded.state.global_params))
    norm_u = update_norms(jax.tree_util.tree_map(
        lambda g: g[None], unguarded.state.global_params))
    assert guarded.corrupted.sum() >= 1
    assert float(norm_g[0]) < float(norm_u[0]) / 10.0


def test_fault_matrix_degradation_curve():
    clients, te, cell, h, params = tiny_world(rounds=8)
    faults = FaultConfig(p_loss=0.3, max_retries=1, p_corrupt=0.3,
                         corrupt_mode="nan")
    cfg = SimConfig(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                    eval_batch=200, data_path="device", faults=faults)
    res = run_fault_matrix(params, mlp_loss, mlp_accuracy, clients, te,
                           RandomScheme(p_bar=0.6, num_clients=5), h, cell,
                           cfg, rates=[0.0, 1.0])
    assert res.acc["guarded"].shape == res.acc["unguarded"].shape
    assert res.finite_final["guarded"].all()
    # the rate-0 lane is the clean world — finite even unguarded
    assert res.finite_final["unguarded"][0]
    # delivered mass can only shrink with severity
    d = res.delivered["guarded"].sum(axis=(1, 2))
    assert d[1] <= d[0]


# --- sparse path: faults + overflow fallback ---------------------------------


SPARSE_KW = dict(local_mode="participants", data_path="device",
                 data_stream="client", participation="sparse")


def test_sparse_faulty_matches_dense():
    clients, te, cell, h, params = tiny_world(rounds=8)
    pol = RandomScheme(p_bar=0.5, num_clients=5)
    base = dict(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200, faults=FAULTS, guards=GUARDS)
    dense = run_simulation(
        params, mlp_loss, mlp_accuracy, clients, te, pol, h, cell,
        SimConfig(**base, **{**SPARSE_KW, "participation": "dense"}))
    sparse = make_sparse_runner(
        mlp_loss, mlp_accuracy, clients, te, pol, cell,
        SimConfig(**base, **SPARSE_KW, participant_bucket=8))(params, h)
    np.testing.assert_array_equal(dense.participation, sparse.participation)
    np.testing.assert_array_equal(dense.delivered, sparse.delivered)
    np.testing.assert_array_equal(dense.corrupted, sparse.corrupted)
    np.testing.assert_allclose(dense.energy_per_client,
                               sparse.energy_per_client, rtol=1e-6)
    np.testing.assert_allclose(dense.energy_timeline, sparse.energy_timeline,
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(dense.state.last_tx),
                                  np.asarray(sparse.state.last_tx))
    for a, b in zip(jax.tree_util.tree_leaves(dense.state.global_params),
                    jax.tree_util.tree_leaves(sparse.state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_sparse_overflow_spills_and_matches():
    """An undersized bucket regrows (warn once) instead of dying; the rerun
    is exact."""
    clients, te, cell, h, params = tiny_world(rounds=8)
    pol = RandomScheme(p_bar=0.9, num_clients=5)
    base = dict(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200)
    ok = make_sparse_runner(
        mlp_loss, mlp_accuracy, clients, te, pol, cell,
        SimConfig(**base, **SPARSE_KW, participant_bucket=8))(params, h)
    import repro.fl.sparse as sparse_mod
    sparse_mod._SPILL_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spilled = make_sparse_runner(
            mlp_loss, mlp_accuracy, clients, te, pol, cell,
            SimConfig(**base, **SPARSE_KW, participant_bucket=2))(params, h)
    assert any("participant bucket overflow" in str(x.message) for x in w)
    np.testing.assert_array_equal(ok.participation, spilled.participation)
    for a, b in zip(jax.tree_util.tree_leaves(ok.state.global_params),
                    jax.tree_util.tree_leaves(spilled.state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_sparse_overflow_error_mode_regression():
    """overflow='error' preserves the legacy hard failure and its message."""
    clients, te, cell, h, params = tiny_world(rounds=8)
    pol = RandomScheme(p_bar=0.9, num_clients=5)
    cfg = SimConfig(rounds=8, local_iters=1, batch_size=8, eval_every=4,
                    eval_batch=200, **SPARSE_KW, participant_bucket=2,
                    overflow="error")
    with pytest.raises(RuntimeError, match=r"participant bucket overflow.*"
                                           r"participant_bucket"):
        make_sparse_runner(mlp_loss, mlp_accuracy, clients, te, pol, cell,
                           cfg)(params, h)


def test_unknown_overflow_policy_rejected():
    clients, te, cell, h, params = tiny_world(rounds=8)
    cfg = SimConfig(rounds=8, **SPARSE_KW, overflow="wrap")
    with pytest.raises(ValueError, match="overflow"):
        make_sparse_runner(mlp_loss, mlp_accuracy, clients, te,
                           RandomScheme(p_bar=0.5, num_clients=5), cell,
                           cfg)(params, h)


# --- properties (hypothesis; skip when the library is absent) ----------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 7), st.integers(0, 2 ** 31 - 1))
def test_property_single_poison_row_never_passes_silently(bad_row, seed):
    """One corrupted client among 8: unguarded aggregation is poisoned
    (non-finite), guarded aggregation is finite AND equals the honest-subset
    aggregate — corruption can't slip through unnoticed in either world."""
    key = jax.random.PRNGKey(seed % (2 ** 31 - 1))
    d = jax.random.normal(key, (8, 16))
    d = d.at[bad_row].set(jnp.nan)
    g = jnp.zeros((16,))
    m = jnp.ones((8,))
    unguarded = masked_aggregate({"w": g}, {"w": d}, m, 8, use_pallas=False)
    assert not all_finite(unguarded)
    guarded = guarded_aggregate({"w": g}, {"w": d}, m, 8,
                                jnp.zeros((8,), jnp.int32),
                                GuardConfig(quarantine=True),
                                use_pallas=False)
    assert all_finite(guarded)
    honest = jnp.sum(jnp.delete(d, bad_row, axis=0), axis=0) / 8.0
    np.testing.assert_allclose(np.asarray(guarded["w"]), np.asarray(honest),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 0.9))
def test_property_guarded_round_stays_finite(seed, p_corrupt):
    """Whatever the corruption rate and draw, a guarded aggregation step
    maps finite global params to finite global params."""
    key = jax.random.PRNGKey(seed % (2 ** 31 - 1))
    k1, k2, k3 = jax.random.split(key, 3)
    d = jax.random.normal(k1, (6, 12)) * 10.0
    flags = jax.random.uniform(k2, (6,)) < p_corrupt
    cfg = FaultConfig(p_corrupt=p_corrupt, corrupt_mode="nan")
    d = corrupt_deltas({"w": d}, flags, cfg.params(), cfg)
    m = (jax.random.uniform(k3, (6,)) < 0.7).astype(jnp.float32)
    out = guarded_aggregate({"w": jnp.ones((12,))}, d, m, 6,
                            jnp.zeros((6,), jnp.int32),
                            GuardConfig(quarantine=True, clip_norm=5.0),
                            use_pallas=False)
    assert all_finite(out)


# --- trace-fitting: FaultParams.from_trace round-trip ------------------------


def test_from_trace_recovers_markov_and_loss_rates():
    """Simulate the actual processes at known rates, fit back from the
    observed traces: the MLE must land within sampling error, and the
    config-level convenience must round-trip into a usable FaultConfig."""
    from repro.fl.faults import FaultParams, fault_key
    cfg = FaultConfig(p_fail=0.15, p_recover=0.4, p_loss=0.2, max_retries=2)
    fp = cfg.params()
    K, T = 256, 400
    key = jax.random.PRNGKey(0)
    avail = jnp.ones((K,), bool)
    mask = jnp.ones((K,), jnp.int32)
    tr_a, tr_att, tr_dlv = [], [], []
    for t in range(T):
        tt = jnp.int32(t)
        avail, _ = markov_availability(tt, fault_key(key, tt, 0), avail,
                                       fp, cfg)
        landed, attempts, _, _ = uplink_process(t, fault_key(key, tt, 2),
                                                mask, fp, cfg)
        tr_a.append(np.asarray(avail))
        tr_att.append(np.asarray(attempts))
        tr_dlv.append(np.asarray(landed))
    fit = FaultParams.from_trace(np.stack(tr_a), attempts=np.stack(tr_att),
                                 delivered=np.stack(tr_dlv))
    assert abs(float(fit.p_fail) - cfg.p_fail) < 0.02
    assert abs(float(fit.p_recover) - cfg.p_recover) < 0.03
    assert abs(float(fit.p_loss) - cfg.p_loss) < 0.02
    fc = FaultConfig.from_trace(np.stack(tr_a), attempts=np.stack(tr_att),
                                delivered=np.stack(tr_dlv), max_retries=2,
                                p_corrupt=0.01)
    assert isinstance(fc, FaultConfig)
    assert fc.max_retries == 2 and fc.p_corrupt == 0.01
    assert abs(fc.p_fail - cfg.p_fail) < 0.02


def test_from_trace_degenerate_and_validation():
    """All-up traces keep the clean-world defaults; malformed inputs raise
    instead of silently fitting garbage."""
    from repro.fl.faults import FaultParams
    fit = FaultParams.from_trace(np.ones((10, 4), bool))
    assert float(fit.p_fail) == 0.0 and float(fit.p_recover) == 1.0
    assert float(fit.p_loss) == 0.0
    # all-down: p_recover estimable, p_fail defaults
    fit2 = FaultParams.from_trace(np.zeros((10, 4), bool))
    assert float(fit2.p_fail) == 0.0 and float(fit2.p_recover) == 0.0
    with pytest.raises(ValueError, match=r"\[T, K\]"):
        FaultParams.from_trace(np.ones((10,), bool))
    with pytest.raises(ValueError, match="together"):
        FaultParams.from_trace(np.ones((4, 2), bool),
                               attempts=np.ones((4, 2)))
    with pytest.raises(ValueError, match="shapes differ"):
        FaultParams.from_trace(np.ones((4, 2), bool),
                               attempts=np.ones((4, 2)),
                               delivered=np.ones((4, 3), bool))
