"""Optional-``hypothesis`` shim: property tests skip on a clean environment.

Test modules do ``from _hypothesis_stub import given, settings, st`` instead of
importing ``hypothesis`` directly.  When the library is installed the real
decorators are re-exported; when it is missing, ``given`` turns the test into
a ``pytest.skip`` and ``st`` strategies become inert placeholders, so the rest
of the module's tests still collect and run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on clean environments
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(_fn):
            def _skipped(*_a, **_k):
                pytest.skip("hypothesis not installed")

            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _InertStrategies:
        """Stands in for ``hypothesis.strategies`` at module-decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _InertStrategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
