"""Golden-trace harness: deterministic tiny runs per scheme × path.

The scheme comparison is only trustworthy if its curves cannot drift
silently between PRs, so this harness pins, for every scheme in the panel
and every execution path (dense scan / legacy host loop / sparse
two-phase):

* the realized participation masks (hashed — threefry PRNG is exact and
  platform-stable, so the hash must match bit-for-bit);
* the loss/accuracy trajectory and the cumulative energy timeline
  (compared with float tolerances — training math may reassociate across
  BLAS builds, physics must not move).

``engine_fingerprint()`` hashes the engine source files; ``traces.json``
records the fingerprint it was generated against.  CI fails when the
fingerprint is stale (engine changed, goldens not regenerated — run
``python tests/golden/regenerate.py``) and when any trace drifts.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
GOLDEN_PATH = Path(__file__).resolve().parent / "traces.json"

#: every source file whose behavior the goldens pin — editing any of these
#: requires regenerating traces.json (the CI fingerprint check enforces it)
ENGINE_SOURCES = [
    "src/repro/fl/engine.py",
    "src/repro/fl/state.py",
    "src/repro/fl/sparse.py",
    "src/repro/fl/simulator.py",
    "src/repro/fl/faults.py",
    "src/repro/fl/schemes.py",
    "src/repro/core/selection.py",
    "src/repro/core/channel.py",
    "src/repro/data/device.py",
]

PATHS = ("dense", "legacy", "sparse")

# trace-compare tolerances: masks/eval grid exact, training floats loose
# enough for BLAS reassociation, tight enough to catch semantic drift
RTOL, ATOL = 1e-4, 1e-5


def engine_fingerprint() -> str:
    h = hashlib.sha256()
    for rel in ENGINE_SOURCES:
        h.update(rel.encode())
        h.update((REPO / rel).read_bytes())
    return h.hexdigest()


def golden_world():
    """Fixed tiny world: 5 clients, 8 rounds, 16-dim MNIST-like shards."""
    import jax
    from repro.core import CellConfig
    from repro.core.channel import channel_gains, sample_positions
    from repro.data import Dataset, make_mnist_like, shard_noniid
    from repro.models.small import init_mlp

    K, T, dim = 5, 8, 16
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=600, n_test=200)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=2)
    clients = [Dataset(c.x[:, :dim], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, T).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 8, 10))
    return clients, te, cell, h, params, K, T


def scheme_panel(K: int):
    """The pinned panel: one lane per aggregator family, policies chosen so
    sparse preconditions hold (state_free or ledger)."""
    from repro.core.selection import (age_aware_policy, csma_policy,
                                      random_policy)
    from repro.fl import AggregatorConfig

    return {
        "paper": (random_policy(0.4, K), AggregatorConfig(kind="paper")),
        "fedasync-hinge": (random_policy(0.4, K),
                           AggregatorConfig(kind="fedasync",
                                            staleness_fn="hinge")),
        "fedasync-poly": (random_policy(0.4, K),
                          AggregatorConfig(kind="fedasync",
                                           staleness_fn="poly")),
        "csmaafl": (csma_policy(2, K), AggregatorConfig(kind="csmaafl")),
        "age-aware": (age_aware_policy(2, K),
                      AggregatorConfig(kind="age")),
    }


def _cfg(T: int, aggregator):
    from repro.fl import SimConfig

    return SimConfig(rounds=T, local_iters=1, batch_size=4, eval_every=2,
                     local_mode="participants", data_path="device",
                     data_stream="client", aggregator=aggregator)


def _trace(result) -> dict:
    import numpy as np

    mask = np.asarray(result.participation)
    return {
        "mask_sha256": hashlib.sha256(
            mask.astype(np.uint8).tobytes()).hexdigest(),
        "eval_rounds": np.asarray(result.eval_rounds).astype(int).tolist(),
        "loss": [float(x) for x in np.asarray(result.test_loss)],
        "acc": [float(x) for x in np.asarray(result.test_acc)],
        "energy_timeline": [float(x) for x in
                            np.asarray(result.energy_timeline)],
    }


def compute_traces() -> dict:
    """Run every scheme on every path; return the golden document."""
    from repro.fl import (make_sparse_runner, run_simulation,
                          run_simulation_legacy)
    from repro.models.small import mlp_accuracy, mlp_loss

    clients, te, cell, h, params, K, T = golden_world()
    traces = {}
    for name, (policy, agg) in scheme_panel(K).items():
        cfg = _cfg(T, agg)
        traces[f"{name}/dense"] = _trace(run_simulation(
            params, mlp_loss, mlp_accuracy, clients, te, policy, h, cell,
            cfg))
        traces[f"{name}/legacy"] = _trace(run_simulation_legacy(
            params, mlp_loss, mlp_accuracy, clients, te, policy, h, cell,
            cfg))
        traces[f"{name}/sparse"] = _trace(make_sparse_runner(
            mlp_loss, mlp_accuracy, clients, te, policy, cell, cfg)(
                params, h))
    return {"fingerprint": engine_fingerprint(), "traces": traces}


def load_goldens() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


def compare_traces(current: dict, golden: dict) -> list[str]:
    """Return a list of human-readable drift descriptions (empty = clean)."""
    import numpy as np

    problems = []
    cur_t, gold_t = current["traces"], golden["traces"]
    for key in sorted(set(cur_t) | set(gold_t)):
        if key not in cur_t:
            problems.append(f"{key}: missing from current run")
            continue
        if key not in gold_t:
            problems.append(f"{key}: not in goldens (regenerate)")
            continue
        c, g = cur_t[key], gold_t[key]
        if c["mask_sha256"] != g["mask_sha256"]:
            problems.append(f"{key}: participation mask hash drifted")
        if c["eval_rounds"] != g["eval_rounds"]:
            problems.append(f"{key}: eval grid drifted")
        for field in ("loss", "acc", "energy_timeline"):
            if not np.allclose(c[field], g[field], rtol=RTOL, atol=ATOL):
                delta = float(np.max(np.abs(
                    np.asarray(c[field]) - np.asarray(g[field]))))
                problems.append(f"{key}: {field} drifted (max |Δ|={delta:g})")
    return problems
