"""Golden-trace regression layer: pinned tiny-run trajectories per
scheme × execution path (see harness.py and regenerate.py)."""
