#!/usr/bin/env python
"""Regenerate (or verify) the golden scheme traces.

Usage (from the repo root, with src/ on PYTHONPATH):

    python tests/golden/regenerate.py                    # rewrite traces.json
    python tests/golden/regenerate.py --check            # recompute + compare
    python tests/golden/regenerate.py --check-fingerprint  # sources vs goldens

``--check-fingerprint`` is the cheap CI gate: it fails (exit 1) when any
engine source file changed since the goldens were generated — no JAX run
involved.  ``--check`` recomputes every scheme × path trace and fails on
drift; regenerate and commit traces.json when the change is intentional.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[2] / "src"))

from golden import harness  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="recompute traces and fail on drift")
    ap.add_argument("--check-fingerprint", action="store_true",
                    help="fail if engine sources changed since generation")
    args = ap.parse_args(argv)

    if args.check_fingerprint:
        golden = harness.load_goldens()
        current = harness.engine_fingerprint()
        if golden["fingerprint"] != current:
            print("STALE: engine sources changed since goldens were "
                  "generated.\n  golden  "
                  f"{golden['fingerprint']}\n  current {current}\n"
                  "Run `python tests/golden/regenerate.py` (and review the "
                  "--check diff) to refresh.")
            return 1
        print(f"fingerprint fresh: {current}")
        return 0

    doc = harness.compute_traces()
    if args.check:
        golden = harness.load_goldens()
        problems = harness.compare_traces(doc, golden)
        if golden["fingerprint"] != doc["fingerprint"]:
            problems.append("engine fingerprint stale (sources changed)")
        if problems:
            print("GOLDEN DRIFT:")
            for p in problems:
                print(f"  - {p}")
            return 1
        print(f"{len(doc['traces'])} traces match the goldens.")
        return 0

    with open(harness.GOLDEN_PATH, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(doc['traces'])} traces -> {harness.GOLDEN_PATH}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
