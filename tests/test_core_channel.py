"""Unit tests: wireless channel model (paper eqs. 4-5, Table II)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import (CellConfig, LN2, channel_gains, path_gain,
                                path_loss_db, rate_bits, rate_nats,
                                sample_positions, tx_energy_j)

CELL = CellConfig()


def test_path_loss_matches_table2():
    # 128.1 + 37.6 log10(r_km): at 1 km the loss is exactly 128.1 dB
    assert np.isclose(float(path_loss_db(jnp.array(1000.0))), 128.1, atol=1e-4)
    # at 100 m: 128.1 - 37.6 = 90.5 dB
    assert np.isclose(float(path_loss_db(jnp.array(100.0))), 90.5, atol=1e-4)


def test_rate_matches_shannon():
    w, h = 0.1, 1e-13
    W, N0, P = CELL.bandwidth_hz, CELL.noise_w_per_hz, CELL.tx_power_w
    snr = P * h / (w * W * N0)
    expect_bits = w * W * np.log2(1 + snr)
    got = float(rate_bits(jnp.array(w), jnp.array(h), P, W, N0))
    assert np.isclose(got, expect_bits, rtol=1e-5)
    assert np.isclose(float(rate_nats(jnp.array(w), jnp.array(h), P, W, N0)),
                      expect_bits * LN2, rtol=1e-5)


def test_rate_zero_bandwidth_is_zero_limit():
    W, N0, P = CELL.bandwidth_hz, CELL.noise_w_per_hz, CELL.tx_power_w
    r = float(rate_nats(jnp.array(0.0), jnp.array(1e-13), P, W, N0))
    assert r >= 0.0 and r < 1.0  # w·ln(1+c/w) → 0 as w → 0


def test_rate_monotone_in_bandwidth_and_gain():
    W, N0, P = CELL.bandwidth_hz, CELL.noise_w_per_hz, CELL.tx_power_w
    ws = jnp.linspace(0.01, 1.0, 32)
    r = np.asarray(rate_nats(ws, jnp.array(1e-13), P, W, N0))
    assert np.all(np.diff(r) > 0)
    hs = jnp.logspace(-16, -11, 32)
    r = np.asarray(rate_nats(jnp.array(0.1), hs, P, W, N0))
    assert np.all(np.diff(r) > 0)


def test_energy_eq5():
    # E = p·P·S/R with S in bits and R in bits/s == S_nats / R_nats
    W, N0, P = CELL.bandwidth_hz, CELL.noise_w_per_hz, CELL.tx_power_w
    p, w, h = 0.5, 0.2, 1e-13
    R_b = float(rate_bits(jnp.array(w), jnp.array(h), P, W, N0))
    expect = p * P * CELL.model_size_bits / R_b
    got = float(tx_energy_j(jnp.array(p), jnp.array(w), jnp.array(h), P, W,
                            N0, CELL.model_size_nats))
    assert np.isclose(got, expect, rtol=1e-5)


def test_positions_within_cell():
    pos = sample_positions(jax.random.PRNGKey(0), CELL)
    assert pos.shape == (CELL.num_clients,)
    assert float(pos.min()) >= CELL.min_radius_m
    assert float(pos.max()) <= CELL.cell_radius_m


def test_positions_annulus():
    pos = sample_positions(jax.random.PRNGKey(0), CELL, r_min=900., r_max=1000.)
    assert float(pos.min()) >= 900.0 and float(pos.max()) <= 1000.0


def test_channel_gains_shape_and_positivity():
    pos = sample_positions(jax.random.PRNGKey(0), CELL)
    h = channel_gains(jax.random.PRNGKey(1), pos, 7)
    assert h.shape == (7, CELL.num_clients)
    assert bool(jnp.all(h > 0))


def test_fading_is_unit_mean():
    pos = jnp.full((CELL.num_clients,), 500.0)
    h = channel_gains(jax.random.PRNGKey(2), pos, 4000)
    mean_ratio = jnp.mean(h / path_gain(pos)[None, :])
    assert np.isclose(float(mean_ratio), 1.0, atol=0.05)
