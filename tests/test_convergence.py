"""Convergence-analysis expressions (Lemma 1, eqs. 7-10, Lemma 3)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core.convergence import (convergence_metric, delta_prime,
                                    expected_delta, lemma1_bound,
                                    theorem1_bound)


def test_delta_prime_eq8():
    p = jnp.full((3, 10), 0.5)
    # Δ' = T / Σ p = 10/5 = 2
    assert np.allclose(np.asarray(delta_prime(p)), 2.0)


def test_expected_delta_geometric():
    """For constant p, eq. (7) approaches the geometric mean (1-p)/p as T→∞."""
    p_val = 0.4
    p = jnp.full((1, 400), p_val)
    e = float(expected_delta(p)[0])
    assert np.isclose(e, (1 - p_val) / p_val, atol=1e-2)


def test_lemma1_monotone_in_delta():
    """Smaller Δ_k ⇒ tighter bound (the Lemma 1 insight)."""
    args = dict(eta=0.01, L=1.0, g_max=5.0, sigma=0.1, f_max=2.0, T=100)
    b_small = float(lemma1_bound(delta=jnp.full((4,), 2.0), **args))
    b_large = float(lemma1_bound(delta=jnp.full((4,), 10.0), **args))
    assert b_small < b_large


def test_theorem1_reduces_to_lemma1():
    p = jnp.full((4, 50), 0.25)  # Δ' = 4
    args = dict(eta=0.01, L=1.0, g_max=5.0, sigma=0.1, f_max=2.0)
    assert np.isclose(float(theorem1_bound(p=p, **args)),
                      float(lemma1_bound(T=50, delta=jnp.full((4,), 4.0), **args)))


def test_lemma3_fairness_optimal():
    """Lemma 3: with a fixed communication budget Σ 1/Δ'_k, the metric is
    minimized by equal Δ'_k (fair participation)."""
    T, K = 60, 4
    budget = 1.2  # Σ_k Σ_t p_{k,t} / T = Σ 1/Δ'
    fair = jnp.full((K, T), budget / K)
    unfair = jnp.stack([
        jnp.full((T,), 0.6), jnp.full((T,), 0.3),
        jnp.full((T,), 0.2), jnp.full((T,), 0.1)])
    assert np.isclose(float(jnp.sum(fair.sum(1))), float(jnp.sum(unfair.sum(1))))
    assert float(convergence_metric(fair)) < float(convergence_metric(unfair))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=0.05, max_value=1.0), min_size=2,
                max_size=6))
def test_property_metric_dominated_by_fair_split(ps):
    """Any participation split is ≥ the fair split with the same budget."""
    K = len(ps)
    T = 20
    p = jnp.tile(jnp.asarray(ps)[:, None], (1, T))
    fair = jnp.full((K, T), float(np.mean(ps)))
    assert float(convergence_metric(fair)) <= float(convergence_metric(p)) + 1e-6


@settings(max_examples=25, deadline=None)
@given(st.floats(min_value=0.02, max_value=0.9),
       st.floats(min_value=0.02, max_value=0.09))
def test_property_lemma2_more_communication_helps(p_hi, dp):
    """Raising every probability lowers the metric (Lemma 2)."""
    T, K = 15, 3
    lo = jnp.full((K, T), p_hi)
    hi = jnp.full((K, T), min(p_hi + dp, 1.0))
    assert float(convergence_metric(hi)) <= float(convergence_metric(lo)) + 1e-9
