"""Model-zoo correctness: causality, prefill↔decode parity, GQA/MoE/SSM
invariants across every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.models import attention, moe
from repro.configs.base import ArchConfig, MoEConfig

ALL_ARCHS = configs.names()


def tiny(name, **kw):
    return configs.get(name).reduced(**kw)


def make_batch(cfg, key, B=2, S=24):
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_loss_finite_and_grad_flows(name):
    cfg = tiny(name)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch = make_batch(cfg, key)
    l, g = jax.value_and_grad(lambda p: T.loss(p, cfg, batch))(params)
    assert np.isfinite(float(l))
    leaves = jax.tree_util.tree_leaves(g)
    gnorm = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in leaves)
    assert np.isfinite(gnorm) and gnorm > 0
    # embedding gradient must flow for token models
    if not cfg.embeds_input:
        assert float(jnp.abs(g["embed"]).max()) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_causality(name):
    """Changing future inputs must not affect past logits."""
    cfg = tiny(name)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S, cut = 1, 16, 8
    if cfg.embeds_input:
        e1 = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        e2 = e1.at[:, cut:].set(jax.random.normal(jax.random.PRNGKey(9),
                                                  (B, S - cut, cfg.d_model)))
        l1, _ = T.forward(params, cfg, embeds=e1)
        l2, _ = T.forward(params, cfg, embeds=e2)
    else:
        t1 = jax.random.randint(key, (B, S), 0, cfg.vocab)
        t2 = t1.at[:, cut:].set((t1[:, cut:] + 1) % cfg.vocab)
        l1, _ = T.forward(params, cfg, tokens=t1)
        l2, _ = T.forward(params, cfg, tokens=t2)
    np.testing.assert_allclose(np.asarray(l1[:, :cut]),
                               np.asarray(l2[:, :cut]), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_parity(name):
    """prefill(t[:k]) then decode steps must reproduce forward() logits.

    Exact for attention; recurrent forms (mamba / mlstm / slstm) use different
    but mathematically equivalent stabilized computations — loose tolerance.
    """
    cfg = tiny(name)
    if cfg.embeds_input:
        cfg = dataclasses.replace(cfg, embeds_input=False)  # decode is tokens
    if cfg.moe is not None:
        # capacity dropping differs between a 12-token forward and 1-token
        # decode batches by design; test routing parity drop-free
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    B, S, k = 1, 12, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full, _ = T.forward(params, cfg, tokens=toks)

    lg, caches = T.prefill(params, cfg, tokens=toks[:, :k], capacity=S)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, k - 1]),
                               atol=2e-2, rtol=2e-2)
    for i in range(k, S):
        lg, caches = T.decode_step(params, cfg, toks[:, i:i + 1], caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, i]),
                                   atol=5e-2, rtol=5e-2)


def test_gqa_equals_mha_when_kv_equals_heads():
    cfg = tiny("llama3.2-1b", n_heads=4, n_kv_heads=4)
    key = jax.random.PRNGKey(3)
    p = attention.init_attn(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 10, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    y = attention.attn_forward(p, cfg, x, pos)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def test_sliding_window_equals_full_when_window_large():
    base = tiny("llama3.2-1b")
    key = jax.random.PRNGKey(4)
    params = T.init_params(key, base)
    toks = jax.random.randint(key, (1, 16), 0, base.vocab)
    full, _ = T.forward(params, base, tokens=toks)
    win = dataclasses.replace(base, sliding_window=64)
    lw, _ = T.forward(params, win, tokens=toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(lw), atol=1e-5)


def test_sliding_window_restricts_receptive_field():
    cfg = dataclasses.replace(tiny("llama3.2-1b"), sliding_window=4)
    key = jax.random.PRNGKey(5)
    params = T.init_params(key, cfg)
    t1 = jax.random.randint(key, (1, 16), 0, cfg.vocab)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab)
    l1, _ = T.forward(params, cfg, tokens=t1)
    l2, _ = T.forward(params, cfg, tokens=t2)
    # with a window of 4 and 2 layers, token 0 cannot influence position 15
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-4)


def test_moe_dispatch_conservation():
    """With ample capacity every token is routed to exactly top_k slots and
    combine weights sum to 1."""
    cfg = dataclasses.replace(
        tiny("qwen3-moe-30b-a3b"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=4.0))
    key = jax.random.PRNGKey(6)
    p = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)

    # reproduce internals to check dispatch mass
    m = cfg.moe
    T_, d = 16, cfg.d_model
    xt = x.reshape(T_, d)
    gates = jax.nn.softmax(xt @ p["router"], axis=-1)
    topv, topi = jax.lax.top_k(gates, m.top_k)
    out, aux = moe.moe_forward(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux) > 0
    # aux loss for a balanced router ≈ 1
    assert 0.5 < float(aux) < 4.0


def test_moe_single_expert_equals_dense():
    """E=1, top1: MoE must equal the dense SwiGLU with that expert's weights."""
    from repro.models.layers import swiglu
    cfg = dataclasses.replace(
        tiny("qwen3-moe-30b-a3b"),
        moe=MoEConfig(num_experts=1, top_k=1, d_ff_expert=32,
                      capacity_factor=8.0))
    key = jax.random.PRNGKey(7)
    p = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 6, cfg.d_model), jnp.float32)
    out, _ = moe.moe_forward(p, cfg, x)
    ref = swiglu(x, p["w1"][0], p["w3"][0], p["w2"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_moe_capacity_drops_overflow():
    """Tiny capacity ⇒ some tokens dropped (output zero for those slots) but
    no NaNs and shape preserved."""
    cfg = dataclasses.replace(
        tiny("qwen3-moe-30b-a3b"),
        moe=MoEConfig(num_experts=2, top_k=1, d_ff_expert=16,
                      capacity_factor=0.1))
    key = jax.random.PRNGKey(8)
    p = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    out, _ = moe.moe_forward(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


def test_mamba_decode_matches_scan():
    from repro.models import mamba as M
    cfg = tiny("jamba-1.5-large-398b")
    key = jax.random.PRNGKey(9)
    p = M.init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 10, cfg.d_model), jnp.float32) * 0.5
    y_par, cache = M.mamba_forward(p, cfg, x, return_cache=True)
    # replay the last token through decode using the cache up to t-1
    y_pre, cache2 = M.mamba_forward(p, cfg, x[:, :9], return_cache=True)
    y_dec, _ = M.mamba_decode(p, cfg, x[:, 9:10], cache2)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_par[:, 9]),
                               atol=1e-4, rtol=1e-4)


def test_xlstm_decode_matches_parallel():
    from repro.models import xlstm as X
    cfg = tiny("xlstm-125m", d_model=64, n_heads=2)
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32) * 0.3

    pm = X.init_mlstm(key, cfg, jnp.float32)
    y_par, _ = X.mlstm_forward(pm, cfg, x, return_cache=True)
    y_pre, cache = X.mlstm_forward(pm, cfg, x[:, :7], return_cache=True)
    y_dec, _ = X.mlstm_decode(pm, cfg, x[:, 7:8], cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]), np.asarray(y_par[:, 7]),
                               atol=2e-3, rtol=2e-2)

    ps = X.init_slstm(key, cfg, jnp.float32)
    y_par2, _ = X.slstm_forward(ps, cfg, x, return_cache=True)
    y_pre2, cache2 = X.slstm_forward(ps, cfg, x[:, :7], return_cache=True)
    y_dec2, _ = X.slstm_decode(ps, cfg, x[:, 7:8], cache2)
    np.testing.assert_allclose(np.asarray(y_dec2[:, 0]),
                               np.asarray(y_par2[:, 7]), atol=1e-4, rtol=1e-4)


def test_ring_buffer_decode_beyond_capacity():
    """Decode past the cache capacity (ring wrap) stays finite."""
    cfg = dataclasses.replace(tiny("llama3.2-1b"), sliding_window=8)
    key = jax.random.PRNGKey(11)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab)
    lg, caches = T.prefill(params, cfg, tokens=toks, capacity=8)
    for i in range(12):  # wraps the 8-slot ring
        lg, caches = T.decode_step(params, cfg, toks[:, :1], caches)
        assert np.isfinite(np.asarray(lg)).all()


# ---------------------------------------------------------------------------
# chunked (streaming) sequence-mixer forms vs direct quadratic references
# ---------------------------------------------------------------------------

def test_chunked_attention_matches_direct():
    from repro.models.attention import (_attend, _attend_chunked, _gqa_scores,
                                        init_attn)
    cfg = tiny("llama3.2-1b", n_heads=4, n_kv_heads=2)
    key = jax.random.PRNGKey(20)
    B, S, hd = 2, 64, cfg.hd
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, 4, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, 2, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, 2, hd), jnp.float32)
    # direct
    scores = _gqa_scores(q, k, cfg)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    direct = _attend(scores, v, (j <= i)[None, None, None])
    # chunked with several block geometries
    for qc, kc in ((16, 16), (8, 32), (32, 8)):
        out = _attend_chunked(q, k, v, cfg, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                                   atol=2e-5, rtol=2e-5)


def test_chunked_attention_sliding_window_matches_direct():
    import dataclasses as dc
    from repro.models.attention import _attend, _attend_chunked, _gqa_scores
    cfg = dc.replace(tiny("llama3.2-1b", n_heads=2, n_kv_heads=2),
                     sliding_window=12)
    key = jax.random.PRNGKey(21)
    B, S, hd = 1, 48, cfg.hd
    q = jax.random.normal(key, (B, S, 2, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(22), (B, S, 2, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(23), (B, S, 2, hd), jnp.float32)
    scores = _gqa_scores(q, k, cfg)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (j <= i) & (j > i - cfg.sliding_window)
    direct = _attend(scores, v, mask[None, None, None])
    out = _attend_chunked(q, k, v, cfg, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               atol=2e-5, rtol=2e-5)


def test_chunked_mamba_matches_single_block():
    from repro.models import mamba as M
    cfg = tiny("jamba-1.5-large-398b")
    key = jax.random.PRNGKey(24)
    p = M.init_mamba(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32) * 0.5
    y_full = M.mamba_forward(p, cfg, x, chunk=64)    # one block
    y_chunk = M.mamba_forward(p, cfg, x, chunk=16)   # 4 blocks
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)


def test_chunked_mlstm_matches_single_block():
    from repro.models import xlstm as X
    cfg = tiny("xlstm-125m", d_model=64, n_heads=2)
    key = jax.random.PRNGKey(25)
    p = X.init_mlstm(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 64, cfg.d_model), jnp.float32) * 0.4
    y_full = X.mlstm_forward(p, cfg, x, chunk=64)
    y_chunk = X.mlstm_forward(p, cfg, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)
    # and against the step recurrence, token by token
    cache = X.init_mlstm_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(64):
        y_t, cache = X.mlstm_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(y_t)
    y_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_rec), np.asarray(y_full),
                               atol=1e-3, rtol=1e-2)
