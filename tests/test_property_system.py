"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core.channel import CellConfig, rate_nats, tx_energy_j
from repro.fl.state import init_fl_state, masked_aggregate, pseudo_gradients

CELL = CellConfig()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_aggregation_linearity(K, seed):
    """eq. (3) is linear in the mask: agg(m1)+agg(m2)-global == agg(m1+m2)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (37,))
    d = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, 37))
    m = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (K,)) < 0.5
         ).astype(jnp.float32)
    m2 = 1.0 - m
    a1 = masked_aggregate(g, d, m, K)
    a2 = masked_aggregate(g, d, m2, K)
    both = masked_aggregate(g, d, jnp.ones((K,)), K)
    np.testing.assert_allclose(np.asarray(a1 + a2 - g), np.asarray(both),
                               atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.floats(0.01, 0.99), st.floats(1e-15, 1e-11))
def test_energy_monotone_decreasing_in_bandwidth(w, h):
    e1 = float(tx_energy_j(jnp.array(1.0), jnp.array(w), jnp.array(h),
                           CELL.tx_power_w, CELL.bandwidth_hz,
                           CELL.noise_w_per_hz, CELL.model_size_nats))
    e2 = float(tx_energy_j(jnp.array(1.0), jnp.array(min(w * 1.5, 1.0)),
                           jnp.array(h), CELL.tx_power_w, CELL.bandwidth_hz,
                           CELL.noise_w_per_hz, CELL.model_size_nats))
    assert e2 <= e1 + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_zero_mask_keeps_global_fixed(K, seed):
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (5, 3))}
    st_ = init_fl_state(params, K)
    moved = jax.tree_util.tree_map(lambda x: x + 1.0, st_.client_params)
    st_ = st_._replace(client_params=moved)
    d = pseudo_gradients(st_)
    out = masked_aggregate(st_.global_params, d, jnp.zeros((K,)), K)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(params["w"]))
