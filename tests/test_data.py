"""Data substrate tests: synthetic generators, non-IID sharding, pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (BatchIterator, Dataset, client_batches, heterogeneity,
                        make_cifar_like, make_mnist_like, make_token_stream,
                        shard_noniid)


def test_mnist_like_shapes():
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=1000, n_test=200)
    assert tr.x.shape == (1000, 784) and te.x.shape == (200, 784)
    assert int(tr.y.max()) <= 9 and int(tr.y.min()) >= 0
    assert float(jnp.abs(tr.x).max()) <= 1.0  # tanh-bounded


def test_cifar_like_shapes():
    tr, te = make_cifar_like(jax.random.PRNGKey(0), n_train=500, n_test=100)
    assert tr.x.shape == (500, 32, 32, 3)


def test_deterministic():
    a, _ = make_mnist_like(jax.random.PRNGKey(7), n_train=100, n_test=10)
    b, _ = make_mnist_like(jax.random.PRNGKey(7), n_train=100, n_test=10)
    assert np.allclose(np.asarray(a.x), np.asarray(b.x))


def test_learnable_structure():
    """A linear probe must beat chance clearly — the data is not noise."""
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=4000, n_test=1000)
    x, y = np.asarray(tr.x), np.asarray(tr.y)
    # closed-form ridge regression on one-hot targets
    Y = np.eye(10)[y]
    Xb = np.concatenate([x, np.ones((len(x), 1))], 1)
    Wt = np.linalg.solve(Xb.T @ Xb + 1e-1 * np.eye(Xb.shape[1]), Xb.T @ Y)
    xt = np.concatenate([np.asarray(te.x), np.ones((len(te.x), 1))], 1)
    acc = float((np.argmax(xt @ Wt, 1) == np.asarray(te.y)).mean())
    assert acc > 0.5


@pytest.mark.parametrize("d", [2, 5, 10])
def test_noniid_sharding(d):
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=2000, n_test=100)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, num_clients=10, d=d)
    assert len(clients) == 10
    total = sum(len(np.asarray(c.y)) for c in clients)
    assert total == 2000
    for c in clients:
        labels = set(np.asarray(c.y).tolist())
        assert len(labels) <= d  # at most d distinct labels per client


def test_noniid_adversarial_d_exceeds_labels():
    """Regression (greedy deadlock): with d > C no shard with an unused
    label exists after the first C slots — the old greedy silently assigned
    fewer than d shards, stranding data.  The relaxed fallback must assign
    every shard: all examples kept, every client non-empty."""
    C, K, d = 10, 2, 15                      # d·K = 30 shards, 3 per class
    n = 600
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.integers(0, C, n), jnp.int32)
    ds = Dataset(jnp.arange(n, dtype=jnp.float32)[:, None], y, C)
    for seed in range(5):                    # deadlock for every shuffle
        clients = shard_noniid(jax.random.PRNGKey(seed), ds, K, d=d)
        assert sum(len(np.asarray(c.y)) for c in clients) == n
        assert all(len(np.asarray(c.y)) > 0 for c in clients)
        # no example lost or duplicated
        seen = np.sort(np.concatenate([np.asarray(c.x)[:, 0]
                                       for c in clients]))
        assert np.array_equal(seen, np.arange(n, dtype=np.float32))


def test_noniid_zero_example_client_raises():
    """A clear error (not np.concatenate([]) crashing) when the data is too
    small to give every client at least one example."""
    ds = Dataset(jnp.ones((3, 2)), jnp.asarray([0, 1, 2], jnp.int32), 10)
    with pytest.raises(ValueError, match="no examples"):
        shard_noniid(jax.random.PRNGKey(0), ds, num_clients=10, d=1)


def test_noniid_heterogeneity_monotone():
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=4000, n_test=100)
    het = [heterogeneity(shard_noniid(jax.random.PRNGKey(1), tr, 10, d))
           for d in (2, 5, 10)]
    assert het[0] > het[1] > het[2]  # smaller d ⇒ more heterogeneous


def test_token_stream():
    ds = make_token_stream(jax.random.PRNGKey(0), n_seqs=4, seq_len=64,
                           vocab=1000)
    assert ds.x.shape == (4, 64)
    assert int(ds.x.max()) < 1000 and int(ds.x.min()) >= 0


def test_batch_iterator_cycles():
    ds = Dataset(jnp.arange(50, dtype=jnp.float32)[:, None],
                 jnp.arange(50) % 10, 10)
    it = BatchIterator(ds, batch_size=16, seed=0)
    seen = set()
    for _ in range(10):
        x, y = next(it)
        assert x.shape == (16, 1)
        seen.update(np.asarray(x)[:, 0].astype(int).tolist())
    assert len(seen) == 50  # full coverage over epochs


def test_client_batches_stacks():
    ds = Dataset(jnp.ones((30, 3)), jnp.zeros((30,), jnp.int32), 10)
    its = [BatchIterator(ds, 8, seed=i) for i in range(4)]
    xb, yb = client_batches(its)
    assert xb.shape == (4, 8, 3) and yb.shape == (4, 8)
