"""Async aggregation front door: admission semantics (validation, dedup,
backpressure, FIFO/age ordering), micro-batch bucketing, the no-drop /
no-double-count invariants under submitter races, policy serving, decision
log round-trips, and the replay-parity contract (a served session re-run
offline through the scan engine reproduces ledgers bit-exactly and the
model to the repo's golden tolerance)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import ProblemSpec, online_policy
from repro.fl.faults import GuardConfig
from repro.fl.state import AggregatorConfig
from repro.serve import (AggregationServer, DecisionLog, LoadGenConfig,
                         ServeConfig, make_client_step, pick_bucket,
                         replay_session, run_loadgen, toy_world,
                         verify_replay)


def _world(K=16, seed=0):
    return toy_world(K, dim=8, classes=4, n_per=6, seed=seed)


def _server(params, K, start=False, **kw):
    cfg = ServeConfig(num_clients=K, local_iters=1, batch_size=3,
                      lr=0.05, seed=0, **kw)
    return AggregationServer(params, cfg, start=start), cfg


def _drive(server, store, loss_fn, uploads, seed=1):
    """Submit `uploads` real client deltas, flushing whenever dedup blocks
    (manual-flush servers) — returns the per-client seq counters used."""
    cfg = server.cfg
    step = make_client_step(store, loss_fn, cfg.local_iters, cfg.batch_size,
                            cfg.seed, lr=cfg.lr)
    rng = np.random.default_rng(seed)
    seqs = np.zeros((cfg.num_clients,), np.int64)
    done = 0
    while done < uploads:
        k = int(rng.integers(cfg.num_clients))
        version, g = server.pull()
        seq = int(seqs[k])
        delta = step(g, k, seq)
        tk = server.submit(k, delta, version, seq=seq,
                           energy_j=float(k + 1) * 0.25)
        if tk.admitted:
            seqs[k] += 1
            done += 1
        else:
            assert tk.reason in ("duplicate", "backpressure")
            server.flush()
    return seqs


# --- unit: bucketing ---------------------------------------------------------


def test_pick_bucket_pow2_and_clamps():
    assert pick_bucket(1, 1, 64) == 1
    assert pick_bucket(3, 1, 64) == 4
    assert pick_bucket(5, 8, 64) == 8        # min_bucket floor
    assert pick_bucket(33, 8, 64) == 64
    assert pick_bucket(200, 8, 64) == 64     # max_batch ceiling
    assert pick_bucket(0, 1, 64) == 1


def test_serve_config_validation():
    with pytest.raises(ValueError, match="power of two"):
        ServeConfig(num_clients=4, max_batch=12)
    with pytest.raises(ValueError, match="min_bucket"):
        ServeConfig(num_clients=4, max_batch=8, min_bucket=16)
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(num_clients=4, admission="lifo")


# --- admission semantics -----------------------------------------------------


def test_submit_validation_dedup_and_close():
    params, store, loss_fn, acc_fn = _world(K=4)
    server, _ = _server(params, 4)
    d = jax.tree_util.tree_map(jnp.zeros_like, params)
    assert server.submit(99, d, 0).reason == "bad_client"
    assert server.submit(-1, d, 0).reason == "bad_client"
    assert server.submit(0, d, 5).reason == "bad_version"   # future anchor
    t1 = server.submit(0, d, 0)
    assert t1.admitted and server.in_flight(0)
    assert server.submit(0, d, 0).reason == "duplicate"
    assert server.flush() == 1
    assert t1.wait(timeout=5) == 1 and server.version == 1
    server.close()
    assert server.submit(1, d, 0).reason == "closed"


def test_backpressure_engages_exactly_at_capacity():
    params, store, loss_fn, acc_fn = _world(K=8)
    server, _ = _server(params, 8, queue_capacity=3)
    d = jax.tree_util.tree_map(jnp.zeros_like, params)
    for k in range(3):
        assert server.submit(k, d, 0).admitted
    tk = server.submit(3, d, 0)
    assert not tk.admitted and tk.reason == "backpressure"
    server.flush()                       # drains the pending set
    assert server.submit(3, d, 0).admitted
    server.close()


def test_age_admission_takes_stalest_first():
    params, store, loss_fn, acc_fn = _world(K=8)
    server, _ = _server(params, 8, admission="age", max_batch=2,
                        min_bucket=1)
    d = jax.tree_util.tree_map(jnp.zeros_like, params)
    # advance the version so distinct anchor ages exist
    for _ in range(3):
        server.submit(0, d, server.version)
        server.flush()
    t = server.version
    server.submit(1, d, t)        # freshest
    server.submit(2, d, t - 2)    # stalest
    server.submit(3, d, t - 1)
    server.flush()
    rec = server.log.records[-1]
    assert list(rec.ids) == [2, 3]          # stalest two admitted first
    assert rec.stale[0] == 2 and rec.stale[1] == 1
    server.close()


# --- replay parity -----------------------------------------------------------


def test_manual_session_replays_bit_exactly():
    params, store, loss_fn, acc_fn = _world(K=16)
    server, _ = _server(params, 16, max_batch=8, min_bucket=2)
    _drive(server, store, loss_fn, uploads=40)
    server.close()
    assert server.version == len(server.log.records) > 0
    rep = verify_replay(server, store, params, loss_fn, acc_fn)
    assert rep["ok"] and rep["n_uploads"] == 40
    # on CPU the live width-1 vmap lane matches the bucketed replay bitwise
    assert rep["model_max_abs_err"] == 0.0


def test_guarded_scheme_session_replays():
    """Guards + a pluggable scheme aggregator flow through the same jitted
    path live and in replay — the precedence mirror is load-bearing."""
    params, store, loss_fn, acc_fn = _world(K=12)
    server, _ = _server(
        params, 12, max_batch=4, min_bucket=2,
        guards=GuardConfig(quarantine=True, clip_norm=5.0,
                           staleness_power=0.5),
        aggregator=AggregatorConfig(kind="csmaafl", staleness_fn="poly"))
    _drive(server, store, loss_fn, uploads=24)
    server.close()
    rep = verify_replay(server, store, params, loss_fn, acc_fn)
    assert rep["ok"] and rep["n_batches"] == server.version


def test_decision_log_roundtrips_through_json(tmp_path):
    params, store, loss_fn, acc_fn = _world(K=8)
    server, _ = _server(params, 8, max_batch=4, min_bucket=2)
    _drive(server, store, loss_fn, uploads=10)
    server.close()
    p = str(tmp_path / "session.json")
    server.log.save(p)
    loaded = DecisionLog.load(p)
    assert loaded.header == server.log.header
    assert loaded.records == server.log.records
    # a replay from the loaded log alone matches the served model
    res = replay_session(loaded, store, params, loss_fn, acc_fn)
    for a, b in zip(jax.tree_util.tree_leaves(res.global_params),
                    jax.tree_util.tree_leaves(server.global_params())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    with pytest.raises(ValueError, match="schema"):
        DecisionLog.from_dict({"header": {"schema": "nope"}, "records": []})


# --- the control plane: p_{k,t} serving --------------------------------------


def test_policy_refresh_serves_probs_and_costs():
    K = 16
    params, store, loss_fn, acc_fn = _world(K=K)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(0), cell)
    gains = channel_gains(jax.random.PRNGKey(1), pos, 8)
    pol = online_policy(ProblemSpec(cell=cell, rho=0.05, num_rounds=8))
    cfg = ServeConfig(num_clients=K, min_bucket=1)
    server = AggregationServer(params, cfg, policy_fn=pol, gains=gains,
                               cell=cell, start=False)
    p = server.transmit_probs()
    assert p.shape == (K,) and np.all(p > 0) and np.all(p <= 1)
    assert server.upload_cost(0) > 0.0
    d = jax.tree_util.tree_map(jnp.zeros_like, params)
    server.submit(3, d, 0)
    server.flush()
    rec = server.log.records[0]
    assert rec.probs[0] == pytest.approx(float(p[3]))  # snapshot at admission
    server.close()
    with pytest.raises(ValueError, match="gains"):
        AggregationServer(params, cfg, policy_fn=pol, start=False)


# --- concurrency: the no-drop / no-double-count stress test ------------------


def test_racing_submitters_never_drop_or_double_count():
    """N threads race the live batcher with a tiny queue: every admitted
    ticket resolves, the ledgers account for exactly the admitted multiset
    (nothing dropped, nothing counted twice), and the bound actually
    engaged (backpressure or dedup rejections were observed)."""
    K = 32
    params, store, loss_fn, acc_fn = _world(K=K)
    cfg = ServeConfig(num_clients=K, queue_capacity=8, max_batch=8,
                      min_bucket=2, flush_interval_s=0.001)
    server = AggregationServer(params, cfg, start=True)
    d = jax.tree_util.tree_map(jnp.zeros_like, params)
    n_threads, per_thread = 8, 40
    admitted: list = []
    rejected: list = []
    alock = threading.Lock()

    def submitter(w):
        rng = np.random.default_rng(w)
        for i in range(per_thread):
            k = int(rng.integers(K))
            tk = server.submit(k, d, server.version)
            with alock:
                (admitted if tk.admitted else rejected).append(tk)

    threads = [threading.Thread(target=submitter, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    server.close(drain=True)           # the no-drop invariant
    assert server._batcher is None

    versions = [tk.wait(timeout=10) for tk in admitted]
    assert all(v is not None for v in versions)            # nothing dropped
    snap = server.ledger_snapshot()
    assert int(snap["tx_count"].sum()) == len(admitted)    # nothing doubled
    logged = [(rec.t, i, s) for rec in server.log.records
              for i, s in zip(rec.ids, rec.seqs)]
    assert len(logged) == len(set(logged)) == len(admitted)
    per_client = np.bincount([tk.client_id for tk in admitted], minlength=K)
    np.testing.assert_array_equal(snap["tx_count"], per_client)
    # the bound engaged: the tiny queue + per-client dedup pushed back
    assert len(rejected) > 0
    assert {tk.reason for tk in rejected} <= {"backpressure", "duplicate"}
    # every resolved version is the batch's t+1 (causality)
    for tk, v in zip(admitted, versions):
        assert 1 <= v <= server.version


def test_batcher_close_is_idempotent_and_context_managed():
    params, store, loss_fn, acc_fn = _world(K=4)
    cfg = ServeConfig(num_clients=4, min_bucket=1)
    with AggregationServer(params, cfg, start=True) as server:
        d = jax.tree_util.tree_map(jnp.zeros_like, params)
        tk = server.submit(0, d, 0)
        assert tk.wait(timeout=10) is not None
    server.close()                     # second close is a no-op
    assert server.version >= 1


# --- end-to-end: the load generator ------------------------------------------


def test_loadgen_session_measures_and_replays():
    K = 24
    params, store, loss_fn, acc_fn = _world(K=K)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    gains = channel_gains(jax.random.PRNGKey(3), pos, 16)
    pol = online_policy(ProblemSpec(cell=cell, rho=0.05, num_rounds=16))
    cfg = ServeConfig(num_clients=K, queue_capacity=64, max_batch=8,
                      min_bucket=2, flush_interval_s=0.002)
    server = AggregationServer(params, cfg, policy_fn=pol, gains=gains,
                               cell=cell, start=True)
    lg = LoadGenConfig(uploads=60, workers=4, seed=0, respect_probs=False,
                       timeout_s=60.0)
    report = run_loadgen(server, store, loss_fn, lg)
    server.close(drain=True)
    assert report["uploads_admitted"] >= lg.uploads
    assert report["uploads_unresolved"] == 0
    assert report["uploads_per_second"] > 0
    assert report["batches"] == server.version > 0
    assert "p95" in report["admit_ms"] and "mean" in report["occupancy"]
    rep = verify_replay(server, store, params, loss_fn, acc_fn)
    assert rep["ok"] and rep["n_uploads"] == report["uploads_admitted"]


def test_loadgen_requires_running_batcher():
    params, store, loss_fn, acc_fn = _world(K=4)
    server, _ = _server(params, 4, start=False)
    with pytest.raises(ValueError, match="batcher"):
        run_loadgen(server, store, loss_fn, LoadGenConfig(uploads=1))
    server.close()
