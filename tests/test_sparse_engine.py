"""Participant-centric sparse rounds: bit-parity with the dense engine in
participants mode, one compile per participant bucket across a K-sweep, the
no-population-sized-buffer guarantee of the training program, overflow
handling, the per-client minibatch stream properties, and the huge-K store
footprint math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (RandomScheme, participant_bucket,
                                  participants_from_mask, random_policy)
from repro.data import Dataset, make_mnist_like, shard_noniid
from repro.data.device import (data_stream_key, estimate_store_bytes,
                               from_client_datasets,
                               gather_participant_rounds,
                               round_indices_client_stream,
                               sample_round_client_stream, store_bytes)
from repro.fl import (FaultConfig, SimConfig, make_runner,
                      run_simulation_legacy)
from repro.fl import sparse as sparse_mod
from repro.fl.sparse import (build_sparse_train_program, resolve_participation)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss
from repro.optim import sgd
from test_device_store import _max_var_elems


def mnist_world(K=8, rounds=10, dim=64):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=1200, n_test=300)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=5)
    clients = [Dataset(c.x[:, :dim], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 24, 10))
    return clients, te, cell, h, params


def synth_world(K, rounds, dim=12, n_per=6, classes=10):
    """K-scalable world: tiny fixed-size per-client shards, synthetic gains
    (shapes stay small at K=1024 where mnist sharding would not)."""
    kx, kh = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (K, n_per, dim))
    y = jnp.tile(jnp.arange(n_per, dtype=jnp.int32) % classes, (K, 1))
    clients = [Dataset(x[k], y[k], classes) for k in range(K)]
    te = Dataset(x[:, 0, :][:64], y[:64, 0], classes)
    cell = CellConfig(num_clients=K)
    h = jax.random.uniform(kh, (K, rounds), minval=1e-14, maxval=1e-12)
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 8, classes))
    return clients, te, cell, h, params


SPARSE_KW = dict(local_mode="participants", data_path="device",
                 data_stream="client")


def run_pair(cfg_base: dict, policy, world, bucket=None):
    """Dense participants-mode runner vs the sparse runner, same config."""
    clients, te, cell, h, params = world
    dense_cfg = SimConfig(**cfg_base, **SPARSE_KW)
    sparse_cfg = SimConfig(**cfg_base, **SPARSE_KW, participation="sparse",
                           participant_bucket=bucket)
    dense = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                        dense_cfg)(params, h)
    sp = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                     sparse_cfg)(params, h)
    return dense, sp


def assert_sparse_parity(dense, sp):
    np.testing.assert_array_equal(dense.participation, sp.participation)
    np.testing.assert_array_equal(dense.eval_rounds, sp.eval_rounds)
    np.testing.assert_allclose(dense.energy_per_client, sp.energy_per_client,
                               rtol=1e-6)
    np.testing.assert_allclose(dense.energy_timeline, sp.energy_timeline,
                               rtol=1e-6)
    np.testing.assert_allclose(dense.test_acc, sp.test_acc, atol=1e-6)
    np.testing.assert_allclose(dense.test_loss, sp.test_loss, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(dense.state.last_tx),
                                  np.asarray(sp.state.last_tx))
    for a, b in zip(jax.tree_util.tree_leaves(dense.state.global_params),
                    jax.tree_util.tree_leaves(sp.state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --- sparse ↔ dense parity ---------------------------------------------------


def test_sparse_matches_dense_bernoulli():
    base = dict(rounds=10, local_iters=2, batch_size=8, eval_every=3,
                eval_batch=200)
    world = mnist_world(rounds=10)
    dense, sp = run_pair(base, RandomScheme(p_bar=0.4, num_clients=8), world,
                         bucket=8)
    assert_sparse_parity(dense, sp)
    # training actually moved the model (parity is not vacuous)
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(
        jax.tree_util.tree_leaves(sp.state.global_params),
        jax.tree_util.tree_leaves(world[4])))


def test_sparse_matches_dense_with_staleness_forcing():
    """Δ_k forced transmissions + aging boost flow through the phase-A
    decision scan (stale anchors, forced-upload energy) identically."""
    base = dict(rounds=12, local_iters=1, batch_size=8, eval_every=4,
                eval_batch=200, max_staleness=3, aging_boost=True)
    world = mnist_world(rounds=12)
    dense, sp = run_pair(base, RandomScheme(p_bar=0.1, num_clients=8), world,
                         bucket=8)
    assert_sparse_parity(dense, sp)
    assert sp.energy_per_client.min() > 0.0   # forcing populated the ledger


def test_sparse_auto_bucket_and_legacy_loop_agree():
    """participant_bucket=None resolves from the expected transmitting mass;
    the legacy host loop in participants mode is a third bit-equal witness."""
    base = dict(rounds=8, local_iters=2, batch_size=8, eval_every=3,
                eval_batch=200)
    world = mnist_world(rounds=8)
    clients, te, cell, h, params = world
    dense, sp = run_pair(base, RandomScheme(p_bar=0.4, num_clients=8), world,
                         bucket=None)
    assert_sparse_parity(dense, sp)
    leg = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients, te,
                                RandomScheme(p_bar=0.4, num_clients=8), h,
                                cell, SimConfig(**base, **SPARSE_KW))
    np.testing.assert_array_equal(sp.participation, leg.participation)
    np.testing.assert_allclose(sp.test_acc, leg.test_acc, atol=1e-6)


# --- dispatch / preconditions ------------------------------------------------


def test_resolve_participation_auto_rules():
    fn = random_policy(0.3, 4)
    ok = SimConfig(**SPARSE_KW, participation="auto")
    assert resolve_participation(ok, fn, "device", 4) == "sparse"
    # any unmet precondition falls back to dense
    for bad in (dict(local_mode="continuous"), dict(data_stream="round")):
        cfg = SimConfig(**{**SPARSE_KW, **bad, "participation": "auto"})
        assert resolve_participation(cfg, fn, "device", 4) == "dense"
    assert resolve_participation(ok, fn, "prestack", 4) == "dense"

    def stateful(t, h_t, state):
        return jnp.zeros_like(h_t), jnp.zeros_like(h_t)

    assert resolve_participation(ok, stateful, "device", 4) == "dense"


def test_sparse_explicit_raises_on_bad_config():
    world = mnist_world(rounds=4)
    clients, te, cell, h, params = world
    pol = RandomScheme(p_bar=0.4, num_clients=8)
    with pytest.raises(ValueError, match="participants"):
        make_runner(mlp_loss, mlp_accuracy, clients, te, pol, cell,
                    SimConfig(rounds=4, data_path="device",
                              data_stream="client", participation="sparse"))
    with pytest.raises(ValueError, match="per-client stream"):
        make_runner(mlp_loss, mlp_accuracy, clients, te, pol, cell,
                    SimConfig(rounds=4, local_mode="participants",
                              data_path="device", participation="sparse"))
    # the client stream itself is device-path-only
    with pytest.raises(ValueError, match="device data path"):
        make_runner(mlp_loss, mlp_accuracy, clients, te, pol, cell,
                    SimConfig(rounds=4, data_path="prestack",
                              data_stream="client"))


def test_bucket_overflow_is_a_hard_error():
    """overflow="error" keeps the legacy hard failure (the default "spill"
    regrows the bucket and reruns — tests/test_faults.py covers that)."""
    world = mnist_world(rounds=6)
    clients, te, cell, h, params = world
    cfg = SimConfig(rounds=6, local_iters=1, batch_size=8, eval_batch=200,
                    **SPARSE_KW, participation="sparse", participant_bucket=4,
                    overflow="error")
    runner = make_runner(mlp_loss, mlp_accuracy, clients, te,
                         RandomScheme(p_bar=1.0, num_clients=8), cell, cfg)
    with pytest.raises(RuntimeError, match="bucket overflow"):
        runner(params, h)


# --- one compile per bucket across a population sweep ------------------------


def test_one_trace_per_bucket_across_K_sweep():
    """K ∈ {64, 256, 1024} with a fixed expected transmitting count share
    one participant bucket — the training program must trace exactly once
    for the whole sweep (its shapes and statics never see K)."""
    T, E, bucket = 6, 4, 16
    base = dict(rounds=T, local_iters=2, batch_size=4, eval_every=3,
                eval_batch=64, **SPARSE_KW, participation="sparse",
                participant_bucket=bucket)
    params = init_mlp(jax.random.PRNGKey(4), dims=(12, 8, 10))
    before = sparse_mod.TRAIN_TRACE_COUNT
    results = {}
    for K in (64, 256, 1024):
        clients, te, cell, h, _ = synth_world(K, T)
        cfg = SimConfig(**base)
        runner = make_runner(mlp_loss, mlp_accuracy, clients, te,
                             RandomScheme(p_bar=E / K, num_clients=K), cell,
                             cfg)
        results[K] = runner(params, h)
    assert sparse_mod.TRAIN_TRACE_COUNT - before == 1
    for K, res in results.items():
        assert res.participation.shape == (T, K)
        assert np.isfinite(res.test_acc).all()
        # realized transmitters stayed population-sparse
        assert res.participation.sum(axis=1).max() <= bucket


def test_participant_bucket_sizing():
    assert participant_bucket(4.0, cap=1 << 20) == 32   # 4 + 6·√4 + 4 → 32
    assert participant_bucket(100.0, cap=1 << 20) == 256
    assert participant_bucket(100.0, cap=64) == 64          # clamped to K
    assert participant_bucket(0.0, cap=1 << 20) >= 8        # floor
    b = participant_bucket(1000.0, cap=1 << 20)
    assert b >= 1000 + 6 * 1000 ** 0.5 and b & (b - 1) == 0


# --- no population-sized buffer in the training program ----------------------


def test_train_program_jaxpr_has_no_K_sized_array():
    """At K = 10⁶ with a bucket of 32, the largest array anywhere in the
    training program's jaxpr stays participant/horizon-sized — no
    [K, N_max] gather, no [K, D] delta stack, not even a [K] vector."""
    K, T, P, L, B, dim = 1_000_000, 8, 32, 2, 4, 12
    cfg = SimConfig(rounds=T, local_iters=L, batch_size=B, eval_every=4,
                    **SPARSE_KW)
    params = init_mlp(jax.random.PRNGKey(0), dims=(dim, 8, 10))
    program = build_sparse_train_program(mlp_loss, mlp_accuracy,
                                         sgd(cfg.lr), cfg)
    args = (params,
            jax.ShapeDtypeStruct((T, P, L, B, dim), jnp.float32),
            jax.ShapeDtypeStruct((T, P, L, B), jnp.int32),
            jax.ShapeDtypeStruct((T, P), jnp.bool_),
            jax.ShapeDtypeStruct((T, P), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((64, dim), jnp.float32),
            jax.ShapeDtypeStruct((64,), jnp.int32))
    max_elems = _max_var_elems(jax.make_jaxpr(program)(*args))
    # the largest array is the gathered participant batch itself (~25k
    # elements) — over an order of magnitude below even a bare [K] vector
    assert max_elems < K // 10, max_elems
    assert max_elems <= T * P * L * B * dim


# --- per-client stream + participant compaction properties -------------------


def test_compaction_is_sorted_padded_and_counted():
    mask = jnp.array([0, 1, 0, 1, 1, 0], jnp.float32)
    idx, valid, n = participants_from_mask(mask, bucket=5)
    assert idx.tolist() == [1, 3, 4, 6, 6]    # ascending, sentinel K=6
    assert valid.tolist() == [True, True, True, False, False]
    assert int(n) == 3


def test_client_stream_rows_independent_of_population():
    """Row k of the dense client-stream reference equals the direct
    per-client draw — the property that lets the sparse path sample only
    its participants."""
    key = data_stream_key(3)
    lens = jnp.array([5, 9, 7, 3], jnp.int32)
    dense = round_indices_client_stream(key, jnp.int32(4), lens, 3, 6)
    from repro.data.device import client_round_indices
    for k in range(4):
        direct = client_round_indices(key, jnp.int32(4), jnp.int32(k),
                                      lens[k], 3, 6)
        np.testing.assert_array_equal(np.asarray(dense[k]),
                                      np.asarray(direct))
    assert bool(jnp.all(dense < lens[:, None, None]))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.integers(0, 7),
       st.lists(st.integers(1, 9), min_size=2, max_size=6),
       st.integers(0, 2 ** 10))
def test_property_participant_gather_matches_dense_stream(seed, t, lens,
                                                          subset_bits):
    """Property (any seed, round, shard sizes, participant subset): sampled
    indices never land in padding, and gathering a participant subset is
    bit-equal to the same rows of the dense client-stream draw."""
    K = len(lens)
    key = data_stream_key(seed)
    lengths = jnp.asarray(lens, jnp.int32)
    idx = round_indices_client_stream(key, jnp.int32(t), lengths, 2, 3)
    assert bool(jnp.all(idx < lengths[:, None, None]))   # never in padding
    assert bool(jnp.all(idx >= 0))

    # store where x rows encode (client, example) uniquely
    clients = [Dataset(
        (jnp.arange(n, dtype=jnp.float32)[:, None] + 100.0 * k)
        * jnp.ones((1, 2)), jnp.full((n,), k % 4, jnp.int32), 4)
        for k, n in enumerate(lens)]
    store = from_client_datasets(clients)
    dense_x, dense_y = sample_round_client_stream(store, key, jnp.int32(t),
                                                  2, 3)
    chosen = [k for k in range(K) if (subset_bits >> k) & 1]
    bucket = max(len(chosen), 1) + 1                     # ≥1 padding lane
    part = jnp.asarray(chosen + [K] * (bucket - len(chosen)), jnp.int32)
    gx, gy = gather_participant_rounds(store, key, part[None, :]
                                       if t == 0 else
                                       jnp.tile(part, (t + 1, 1)), 2, 3)
    for p, k in enumerate(chosen):
        np.testing.assert_array_equal(np.asarray(gx[t, p]),
                                      np.asarray(dense_x[k]))
        np.testing.assert_array_equal(np.asarray(gy[t, p]),
                                      np.asarray(dense_y[k]))


# --- huge-K store footprint math (the planner the sparse path relies on) -----


def test_store_bytes_matches_built_store_exactly():
    clients = [Dataset(jnp.ones((n, 5)), jnp.zeros((n,), jnp.int32), 3)
               for n in (4, 9, 6)]
    store = from_client_datasets(clients)
    assert estimate_store_bytes(clients) == store.nbytes


def test_store_bytes_counts_mask_blocks_and_survives_huge_K():
    """The [K, N_max] int32 label block and the [K] lengths vector are part
    of the footprint (the old estimate missed them), and K ~ 10⁹ planning
    queries stay exact Python ints — no fixed-width overflow."""
    K, cap, dim = 10 ** 9, 64, 784
    b = store_bytes(K, cap, (dim,))
    assert b == K * cap * (dim * 4 + 4) + K * 4
    assert isinstance(b, int) and b > 2 ** 31          # far past int32
    small = store_bytes(2, 3, (5,))
    clients = [Dataset(jnp.ones((3, 5)), jnp.zeros((3,), jnp.int32), 2)
               for _ in range(2)]
    assert small == from_client_datasets(clients).nbytes


def test_degenerate_partition_rejected_before_bincount():
    """K > N cannot leave every client non-empty: the cap readback must
    refuse early (before materializing a [K]-sized bincount)."""
    from repro.data.device import _default_cap
    assign = jnp.zeros((10,), jnp.int32)
    with pytest.raises(ValueError, match="degenerate"):
        _default_cap(assign, num_clients=10 ** 8)
    with pytest.raises(ValueError, match="no examples"):
        _default_cap(assign, num_clients=2)            # all mass on client 0


# --- phase A full round hoist: [T, K] decision matrix vs the serial scan ----


def _phase_a_outputs(cfg, pol, cell, h, K, bucket, base_key, hoist):
    prog = sparse_mod.build_participation_program(pol, cfg, cell, K, bucket,
                                                  hoist_rounds=hoist)
    return jax.jit(prog)(h, base_key)


@pytest.mark.parametrize("with_taps", [False, True])
def test_hoisted_phase_a_matches_serial_scan(with_taps):
    """State-free policies with no sequential state (faults/max_staleness)
    hoist the whole horizon into one vmap: masks, index sets, anchor slots,
    staleness and last_tx must be bit-identical to the scanned recurrence,
    the energy ledger equal to summation-order tolerance."""
    from repro.core.selection import ProblemSpec, online_policy
    K, T, bucket = 48, 30, 32
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(0), cell)
    h = channel_gains(jax.random.PRNGKey(1), pos, T)
    pol = online_policy(ProblemSpec(cell=cell, rho=0.05, num_rounds=T))
    kw = {}
    if with_taps:
        from repro.obs.taps import MetricsSpec
        kw["metrics"] = MetricsSpec(participation=True, staleness_hist=True,
                                    energy_by_cause=True)
    cfg = SimConfig(rounds=T, local_iters=1, batch_size=4, lr=0.01, **kw)
    base_key = jax.random.PRNGKey(7)
    rs = _phase_a_outputs(cfg, pol, cell, h, K, bucket, base_key, False)
    rh = _phase_a_outputs(cfg, pol, cell, h, K, bucket, base_key, True)
    np.testing.assert_array_equal(np.asarray(rs[0]), np.asarray(rh[0]))
    np.testing.assert_allclose(np.asarray(rs[1]), np.asarray(rh[1]),
                               rtol=1e-6, atol=1e-8)
    for name, a, b in zip(rs[2]._fields, rs[2], rh[2]):
        if a is None:
            assert b is None, name
            continue
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8,
                                       err_msg=name)
        else:
            np.testing.assert_array_equal(a, b, err_msg=name)
    if with_taps:
        ms, mh = rs[3], rh[3]
        np.testing.assert_array_equal(np.asarray(ms.tx_count),
                                      np.asarray(mh.tx_count))
        np.testing.assert_array_equal(np.asarray(ms.stale_hist),
                                      np.asarray(mh.stale_hist))
        np.testing.assert_allclose(np.asarray(ms.energy_cause),
                                   np.asarray(mh.energy_cause), rtol=1e-6)


def test_hoist_refuses_sequential_state():
    """Forcing hoist_rounds=True under faults or max_staleness must raise —
    both thread per-round state that a horizon vmap cannot carry."""
    K, T, bucket = 8, 5, 8
    cell = CellConfig(num_clients=K)
    pol = random_policy(0.5, K)
    cfg_f = SimConfig(rounds=T, local_iters=1, batch_size=4, lr=0.01,
                      faults=FaultConfig(p_loss=0.1))
    with pytest.raises(ValueError, match="hoist_rounds"):
        sparse_mod.build_participation_program(pol, cfg_f, cell, K, bucket,
                                               hoist_rounds=True)
    cfg_s = SimConfig(rounds=T, local_iters=1, batch_size=4, lr=0.01,
                      max_staleness=3)
    with pytest.raises(ValueError, match="hoist_rounds"):
        sparse_mod.build_participation_program(pol, cfg_s, cell, K, bucket,
                                               hoist_rounds=True)
    # auto-select under faults silently stays on the scan and still runs
    pos = sample_positions(jax.random.PRNGKey(0), cell)
    h = channel_gains(jax.random.PRNGKey(1), pos, T)
    prog = sparse_mod.build_participation_program(pol, cfg_f, cell, K, bucket)
    out = jax.jit(prog)(h, jax.random.PRNGKey(2))
    assert np.asarray(out[0]).shape == (K,)
