"""Resumable scans: chunked segments == one monolithic scan bit-wise,
kill-and-resume reproduces the uninterrupted run exactly (faults included),
replay-mode post-hoc evals, and the checkpoint-directory guard rails."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import RandomScheme
from repro.data import make_mnist_like, shard_noniid
from repro.data.synthetic import Dataset
from repro.fl import (FaultConfig, GuardConfig, SimConfig, run_resumable,
                      run_simulation, segment_bounds)
from repro.fl.resume import completed_segments
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

DIM = 64
K, T = 5, 12


def tiny_world():
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=1000, n_test=300)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=2)
    clients = [Dataset(c.x[:, :DIM], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :DIM], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, T).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(DIM, 24, 10))
    return clients, te, cell, h, params


BASE = dict(rounds=T, local_iters=1, batch_size=8, eval_every=4,
            eval_batch=200, data_path="device")
POLICY = RandomScheme(p_bar=0.5, num_clients=K)


def leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_segment_bounds_cover_the_horizon():
    assert segment_bounds(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert segment_bounds(8, 4) == [(0, 4), (4, 8)]
    assert segment_bounds(3, 100) == [(0, 3)]


def test_chunked_equals_single_scan(tmp_path):
    """Segmenting the horizon changes neither PRNG streams nor op order:
    the resumable driver's result is bit-identical to the monolithic scan."""
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**BASE, checkpoint_every=5)
    whole = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                           POLICY, h, cell, cfg)
    seg = run_resumable(params, mlp_loss, mlp_accuracy, clients, te, POLICY,
                        h, cell, cfg, str(tmp_path))
    leaves_equal(whole.state.global_params, seg.state.global_params)
    np.testing.assert_array_equal(whole.eval_rounds, seg.eval_rounds)
    np.testing.assert_allclose(whole.test_acc, seg.test_acc)
    np.testing.assert_allclose(whole.energy_per_client,
                               seg.energy_per_client, rtol=1e-6)
    np.testing.assert_array_equal(whole.participation, seg.participation)


def test_kill_and_resume_reproduces_exactly(tmp_path):
    """Stop after one committed segment (the simulated kill), resume in a
    fresh call: final params match the uninterrupted run bit-for-bit —
    faults, guards and all."""
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**BASE, checkpoint_every=4,
                    faults=FaultConfig(p_loss=0.3, max_retries=1,
                                       p_corrupt=0.3, corrupt_mode="nan"),
                    guards=GuardConfig(quarantine=True, clip_norm=10.0))
    whole = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                           POLICY, h, cell, cfg)
    killed = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                           POLICY, h, cell, cfg, str(tmp_path),
                           stop_after_segment=1)
    assert killed is None
    assert completed_segments(str(tmp_path), len(segment_bounds(T, 4))) == 1
    resumed = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                            POLICY, h, cell, cfg, str(tmp_path))
    leaves_equal(whole.state.global_params, resumed.state.global_params)
    np.testing.assert_array_equal(whole.delivered, resumed.delivered)
    np.testing.assert_array_equal(whole.corrupted, resumed.corrupted)
    np.testing.assert_allclose(whole.test_acc, resumed.test_acc)


def test_resume_skips_completed_segments(tmp_path):
    """A second call on a finished directory re-runs nothing (all markers
    present) and still reassembles the full result."""
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**BASE, checkpoint_every=4)
    first = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                          POLICY, h, cell, cfg, str(tmp_path))
    n_seg = len(segment_bounds(T, 4))
    assert completed_segments(str(tmp_path), n_seg) == n_seg
    again = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                          POLICY, h, cell, cfg, str(tmp_path))
    leaves_equal(first.state.global_params, again.state.global_params)
    np.testing.assert_allclose(first.test_acc, again.test_acc)


def test_replay_eval_mode_boundary_checkpoints(tmp_path):
    """eval_mode='replay' removes the in-scan lax.cond eval; the strided
    post-hoc evals land on segment boundaries and the final params match the
    inscan engine bit-wise."""
    clients, te, cell, h, params = tiny_world()
    inscan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                            POLICY, h, cell, SimConfig(**BASE))
    cfg = SimConfig(**BASE, eval_mode="replay", checkpoint_every=4)
    rep = run_resumable(params, mlp_loss, mlp_accuracy, clients, te, POLICY,
                        h, cell, cfg, str(tmp_path))
    leaves_equal(inscan.state.global_params, rep.state.global_params)
    np.testing.assert_array_equal(rep.eval_rounds, [3, 7, 11])
    assert np.isfinite(rep.test_acc).all()
    # the last boundary is the final model — its eval must agree with the
    # inscan engine's final-round eval
    np.testing.assert_allclose(rep.test_acc[-1], inscan.test_acc[-1],
                               atol=1e-6)


def test_fingerprint_mismatch_rejected(tmp_path):
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**BASE, checkpoint_every=4)
    run_resumable(params, mlp_loss, mlp_accuracy, clients, te, POLICY, h,
                  cell, cfg, str(tmp_path), stop_after_segment=1)
    other = SimConfig(**{**BASE, "seed": 99}, checkpoint_every=4)
    with pytest.raises(ValueError, match="different run"):
        run_resumable(params, mlp_loss, mlp_accuracy, clients, te, POLICY,
                      h, cell, other, str(tmp_path))


def test_prestack_path_cannot_resume(tmp_path):
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**{**BASE, "data_path": "prestack"}, checkpoint_every=4)
    with pytest.raises(ValueError, match="prestack"):
        run_resumable(params, mlp_loss, mlp_accuracy, clients, te, POLICY,
                      h, cell, cfg, str(tmp_path))


def test_marker_gap_truncates_restore(tmp_path):
    """A missing .done marker ends the committed prefix: later orphan
    segments are rerun, and the result is still exact."""
    clients, te, cell, h, params = tiny_world()
    cfg = SimConfig(**BASE, checkpoint_every=4)
    whole = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                          POLICY, h, cell, cfg, str(tmp_path))
    os.remove(os.path.join(str(tmp_path), "seg_00001.done"))
    n_seg = len(segment_bounds(T, 4))
    assert completed_segments(str(tmp_path), n_seg) == 1
    redone = run_resumable(params, mlp_loss, mlp_accuracy, clients, te,
                           POLICY, h, cell, cfg, str(tmp_path))
    leaves_equal(whole.state.global_params, redone.state.global_params)
