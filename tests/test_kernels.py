"""Per-kernel validation (deliverable c): shape/dtype sweeps, interpret-mode
Pallas vs the pure-jnp oracle in ref.py, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.kernels import ref
from repro.kernels.fl_aggregate import fl_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.selective_scan import selective_scan

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ---------------------------------------------------------------------------
# fl_aggregate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 4, 16, 32])
@pytest.mark.parametrize("M", [128, 8192, 8193, 77])   # incl. non-tile sizes
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fl_aggregate_sweep(K, M, dtype):
    key = jax.random.PRNGKey(K * 1000 + M)
    g = jax.random.normal(key, (M,), dtype)
    d = jax.random.normal(jax.random.PRNGKey(1), (K, M), dtype)
    m = (jax.random.uniform(jax.random.PRNGKey(2), (K,)) < 0.5
         ).astype(jnp.float32)
    out = fl_aggregate(g, d, m, interpret=True)
    want = ref.fl_aggregate_ref(g, d, m)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_fl_aggregate_zero_mask_is_identity():
    g = jnp.arange(300.0)
    d = jnp.ones((8, 300))
    out = fl_aggregate(g, d, jnp.zeros((8,)), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(1, 500))
def test_fl_aggregate_property(K, M):
    """Full mask ⇒ exactly global + mean(deltas)."""
    d = jnp.ones((K, M)) * 2.0
    g = jnp.zeros((M,))
    out = fl_aggregate(g, d, jnp.ones((K,)), interpret=True)
    np.testing.assert_allclose(np.asarray(out), 2.0, atol=1e-6)


@pytest.mark.parametrize("M", [128, 8193, 77])
def test_fl_aggregate_guard_zeroes_nonfinite(M):
    """guard=True quarantines NaN/Inf elements inside the kernel — the
    result matches the sanitizing oracle and never goes non-finite."""
    g = jax.random.normal(jax.random.PRNGKey(0), (M,))
    d = jax.random.normal(jax.random.PRNGKey(1), (4, M))
    d = d.at[1].set(jnp.nan).at[2, 0].set(jnp.inf)
    w = jnp.array([0.25, 0.25, 0.0, 0.25])
    out = fl_aggregate(g, d, w, interpret=True, denom=1, guard=True)
    want = ref.fl_aggregate_guarded_ref(g, d, w)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **TOL[jnp.float32])


def test_fl_aggregate_guard_off_propagates_nan():
    """Without the guard a poisoned row does reach the output — the
    regression that makes quarantine necessary."""
    g = jnp.zeros((128,))
    d = jnp.zeros((2, 128)).at[0].set(jnp.nan)
    out = fl_aggregate(g, d, jnp.ones((2,)), interpret=True)
    assert np.isnan(np.asarray(out)).any()


def test_fl_aggregate_guarded_ref_matches_manual():
    g = jnp.ones((5,))
    d = jnp.stack([jnp.full((5,), 2.0), jnp.full((5,), jnp.nan)])
    w = jnp.array([0.5, 0.5])
    out = ref.fl_aggregate_guarded_ref(g, d, w)
    np.testing.assert_allclose(np.asarray(out), 2.0)  # 1 + 0.5·2 + 0.5·0


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 2, 2, 64),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2:1
    (1, 256, 8, 2, 128),     # GQA 4:1, wide head
    (1, 512, 4, 1, 64),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, window=window, bq=64, bk=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_geometry(bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_first_token_attends_self_only():
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# selective_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,d,N", [
    (1, 64, 128, 16),
    (2, 256, 512, 16),
    (1, 128, 256, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_sweep(B, S, d, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + d), 6)
    xc = jax.random.normal(ks[0], (B, S, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d), dtype) - 1)
    Bm = jax.random.normal(ks[2], (B, S, N), dtype)
    Cm = jax.random.normal(ks[3], (B, S, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (d, N)) * 0.3)
    Dv = jax.random.normal(ks[5], (d,))
    out = selective_scan(xc, dt, Bm, Cm, A, Dv, bd=128, sc=64, interpret=True)
    want = ref.selective_scan_ref(xc.astype(jnp.float32),
                                  dt.astype(jnp.float32),
                                  Bm.astype(jnp.float32),
                                  Cm.astype(jnp.float32), A, Dv)
    tol = dict(atol=1e-4, rtol=1e-3) if dtype == jnp.float32 \
        else dict(atol=0.15, rtol=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **tol)


def test_selective_scan_state_carries_across_blocks():
    """A single long block vs many small sequential blocks must agree —
    proves the VMEM scratch state survives grid steps."""
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    B, S, d, N = 1, 256, 128, 16
    xc = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d)) - 1)
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (d, N)) * 0.3)
    Dv = jax.random.normal(ks[5], (d,))
    one = selective_scan(xc, dt, Bm, Cm, A, Dv, bd=128, sc=256,
                         interpret=True)
    many = selective_scan(xc, dt, Bm, Cm, A, Dv, bd=128, sc=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many),
                               atol=1e-4, rtol=1e-4)
