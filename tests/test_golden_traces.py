"""Golden-trace regression: every scheme × path trajectory is pinned.

A failure here means a code change moved a scheme curve.  If intentional,
regenerate with `python tests/golden/regenerate.py` and commit the new
traces.json alongside the change; if not, you just caught a regression
the parity tests can't see (they compare paths to each other, not to
history)."""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))

from golden import harness  # noqa: E402


@pytest.fixture(scope="module")
def current():
    return harness.compute_traces()


@pytest.fixture(scope="module")
def golden():
    if not harness.GOLDEN_PATH.exists():
        pytest.skip("goldens not generated yet "
                    "(run python tests/golden/regenerate.py)")
    return harness.load_goldens()


def test_fingerprint_fresh(golden):
    assert golden["fingerprint"] == harness.engine_fingerprint(), (
        "engine sources changed since the goldens were generated — run "
        "`python tests/golden/regenerate.py` and review the diff")


def test_every_scheme_and_path_pinned(golden):
    K = 5
    want = {f"{name}/{path}" for name in harness.scheme_panel(K)
            for path in harness.PATHS}
    assert set(golden["traces"]) == want


def test_no_trace_drift(current, golden):
    problems = harness.compare_traces(current, golden)
    assert not problems, "\n".join(problems)


def test_masks_identical_across_paths(current):
    # the fold_in PRNG contract, pinned through the goldens: all three
    # paths realize the identical participation masks
    traces = current["traces"]
    names = {k.split("/")[0] for k in traces}
    for name in names:
        hashes = {traces[f"{name}/{p}"]["mask_sha256"]
                  for p in harness.PATHS}
        assert len(hashes) == 1, f"{name}: paths realized different masks"
