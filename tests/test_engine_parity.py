"""Scan engine vs legacy host loop: bit-wise agreement on identical PRNG
streams, plus the staleness paths (Δ_k forced transmission, aging boost,
forced-upload energy ledger) and the corrected forced-transmit bandwidth
reservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (ProposedOnline, RandomScheme, as_policy_fn,
                                  random_policy)
from repro.data import make_mnist_like, shard_noniid
from repro.data.synthetic import Dataset
from repro.fl import (SimConfig, grant_forced_bandwidth, run_simulation,
                      run_simulation_legacy)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss


def tiny_world(K=5, rounds=8, dim=64):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=1000, n_test=300)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=2)
    clients = [Dataset(c.x[:, :dim], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 24, 10))
    return clients, te, cell, h, params


def both_engines(cfg, policy, K=5, rounds=8):
    clients, te, cell, h, params = tiny_world(K=K, rounds=rounds)
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, policy, h, cell, cfg)
    return scan, legacy


def assert_parity(scan, legacy):
    # identical fold_in(seed, t) streams ⇒ identical realized masks
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_array_equal(scan.eval_rounds, legacy.eval_rounds)
    np.testing.assert_allclose(scan.energy_per_client,
                               legacy.energy_per_client, rtol=1e-6)
    np.testing.assert_allclose(scan.energy_timeline, legacy.energy_timeline,
                               rtol=1e-6)
    np.testing.assert_allclose(scan.test_acc, legacy.test_acc, atol=1e-6)
    np.testing.assert_allclose(scan.test_loss, legacy.test_loss, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(scan.state.global_params),
                    jax.tree_util.tree_leaves(legacy.state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --- scan ↔ legacy parity ---------------------------------------------------


def test_parity_plain_bernoulli():
    cfg = SimConfig(rounds=8, local_iters=2, batch_size=8, eval_every=3,
                    eval_batch=200)
    assert_parity(*both_engines(cfg, RandomScheme(p_bar=0.4, num_clients=5)))


def test_parity_staleness_aging_and_forced_energy():
    """Δ_k forced transmission + aging boost + forced-upload energy ledger:
    the scan carry reproduces the host loop bit-wise."""
    cfg = SimConfig(rounds=10, local_iters=1, batch_size=8, eval_every=4,
                    max_staleness=2, aging_boost=True, eval_batch=200)
    scan, legacy = both_engines(cfg, RandomScheme(p_bar=0.05, num_clients=5),
                                rounds=10)
    assert_parity(scan, legacy)
    # with p̄ ≈ 0 the ledger is dominated by forced uploads — it must be
    # populated (a forced client pays P·S/R in the round it is forced)
    assert scan.energy_per_client.min() > 0.0
    # Δ_k=2 enforcement visible in the realized masks
    for k in range(5):
        tx = np.where(scan.participation[:, k] > 0)[0]
        assert len(tx) >= 4 and np.diff(tx).max() <= 2


def test_parity_online_policy():
    cfg = SimConfig(rounds=6, local_iters=1, batch_size=8, eval_every=3,
                    eval_batch=200)
    cell = CellConfig(num_clients=5)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=6)
    assert_parity(*both_engines(cfg, ProposedOnline(spec), rounds=6))


def test_scan_accepts_pure_policy_fn():
    """The engine-native interface: a bare PolicyFn, no legacy object."""
    cfg = SimConfig(rounds=4, local_iters=1, batch_size=8, eval_every=2,
                    eval_batch=200)
    clients, te, cell, h, params = tiny_world(rounds=4)
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         random_policy(0.5, 5), h, cell, cfg)
    assert res.participation.shape == (4, 5)
    assert np.isfinite(res.test_acc).all()


# --- forced-transmit bandwidth reservation (the fixed rescale) --------------


def test_forced_grant_leaves_nonforced_untouched_when_slack():
    """The old bug renormalized *all* clients even when the grant fit; the
    fix must keep non-forced clients at their server-optimal allocation
    whenever Σw ≤ 1 holds after granting."""
    w = jnp.array([0.2, 0.2, 0.05], jnp.float32)
    forced = jnp.array([False, False, True])
    out = np.asarray(grant_forced_bandwidth(w, forced, 3))
    np.testing.assert_allclose(out, [0.2, 0.2, 1.0 / 3.0], rtol=1e-6)


def test_forced_grant_shrinks_nonforced_only_when_overflowing():
    w = jnp.array([0.5, 0.3, 0.01, 0.01], jnp.float32)
    forced = jnp.array([False, False, True, True])
    out = np.asarray(grant_forced_bandwidth(w, forced, 4))
    # forced clients keep their full 1/K grant...
    np.testing.assert_allclose(out[2:], 0.25, rtol=1e-6)
    # ...and non-forced shrink proportionally into the remaining room
    np.testing.assert_allclose(out[0] / out[1], 0.5 / 0.3, rtol=1e-6)
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-6)


def test_forced_grant_positive_even_with_zero_slack():
    """Regression: greedy/age give unselected clients w = 0 and selected
    clients the whole band; a Δ_k-forced unselected client must still get a
    positive slice (w = 0 ⇒ the eq.-5 energy ledger explodes)."""
    w = jnp.array([0.5, 0.5, 0.0, 0.0], jnp.float32)   # greedy k=2, K=4
    forced = jnp.array([False, False, True, False])
    out = np.asarray(grant_forced_bandwidth(w, forced, 4))
    np.testing.assert_allclose(out[2], 0.25, rtol=1e-6)   # full 1/K grant
    np.testing.assert_allclose(out[:2], 0.375, rtol=1e-6)
    np.testing.assert_allclose(out.sum(), 1.0 - 0.0, atol=1e-6)


def test_forced_grant_identity_without_forced():
    w = jnp.array([0.4, 0.3, 0.3], jnp.float32)
    forced = jnp.zeros((3,), bool)
    np.testing.assert_array_equal(np.asarray(grant_forced_bandwidth(w, forced,
                                                                    3)),
                                  np.asarray(w))


def test_forced_grant_total_never_exceeds_one():
    key = jax.random.PRNGKey(0)
    for i in range(20):
        k1, k2, key = jax.random.split(jax.random.fold_in(key, i), 3)
        w = jax.random.dirichlet(k1, jnp.ones((8,))) * 0.9
        forced = jax.random.uniform(k2, (8,)) < 0.4
        out = np.asarray(grant_forced_bandwidth(w.astype(jnp.float32),
                                                forced, 8))
        assert out.sum() <= 1.0 + 1e-5
        # every forced client ends with a strictly positive slice
        assert np.all(out[np.asarray(forced)] > 0.0)


def test_greedy_with_staleness_has_sane_energy():
    """End-to-end regression for the zero-slack grant: greedy + Δ_k forcing
    must not produce astronomically large forced-upload energies."""
    from repro.core.selection import GreedyScheme
    cfg = SimConfig(rounds=10, local_iters=1, batch_size=8, eval_every=20,
                    max_staleness=3, eval_batch=200)
    scan, legacy = both_engines(cfg, GreedyScheme(k=2, num_clients=5),
                                rounds=10)
    assert_parity(scan, legacy)
    # all clients transmit (forced at least every 3 rounds) at plausible cost
    assert scan.energy_per_client.min() > 0.0
    assert scan.energy_per_client.max() < 1e4


# --- aging boost ------------------------------------------------------------


def test_aging_boost_lifts_probability_with_staleness():
    """p' = 1 − (1−p)(1−boost) is monotone in staleness and reaches 1 at Δ."""
    from repro.fl.engine import round_decision
    from repro.fl.state import init_fl_state

    K = 4
    cell = CellConfig(num_clients=K)
    cfg = SimConfig(rounds=10, max_staleness=4, aging_boost=True)
    params = {"w": jnp.zeros((3,))}
    state = init_fl_state(params, K)
    # round 4, last_tx staggered 0..3 ⇒ staleness 4,3,2,1
    state = state._replace(round=jnp.int32(4),
                           last_tx=jnp.arange(K, dtype=jnp.int32))
    h_t = jnp.full((K,), 1e-13)
    mask, forced, w, e = round_decision(
        as_policy_fn(random_policy(0.1, K)), jnp.int32(4), h_t, state,
        jax.random.PRNGKey(0), cfg, cell, K)
    # staleness 4 ≥ Δ ⇒ client 0 transmits with certainty (forced if unlucky)
    assert float(mask[0]) == 1.0
    # boost itself: recompute probs the way the engine does
    stale = (4 - np.arange(K)) / 4.0
    boost = np.clip(stale, 0, 1) ** 2
    probs = 1 - (1 - 0.1) * (1 - boost)
    assert np.all(np.diff(probs) < 0)  # decreasing staleness ⇒ smaller lift
    assert probs[0] == 1.0
