"""Consistency between the analytic parameter accounting used by the
roofline/mode selection and the actual initialized models."""
import jax
import pytest

from repro import configs
from repro.fl.distributed import mode_for, param_count
from repro.models import transformer as T

try:
    from benchmarks.roofline import active_param_count  # noqa
    HAVE_ROOFLINE = True
except Exception:
    HAVE_ROOFLINE = False


@pytest.mark.parametrize("name", configs.names())
def test_param_count_matches_init(name):
    cfg = configs.get(name).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    analytic = param_count(cfg)
    assert analytic == actual, (analytic, actual)


def test_full_config_param_totals():
    """Sanity-check the headline parameter counts of the assigned configs."""
    expect = {
        "jamba-1.5-large-398b": (380e9, 430e9),
        "chameleon-34b": (30e9, 38e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "llama4-maverick-400b-a17b": (370e9, 420e9),
        "phi4-mini-3.8b": (3.3e9, 4.3e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        "xlstm-125m": (0.09e9, 0.16e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_count(configs.get(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_mode_selection():
    assert mode_for(configs.get("jamba-1.5-large-398b")) == "masked_dp"
    assert mode_for(configs.get("llama4-maverick-400b-a17b")) == "masked_dp"
    for small in ("llama3.2-1b", "qwen3-moe-30b-a3b", "chameleon-34b",
                  "xlstm-125m"):
        assert mode_for(configs.get(small)) == "replica"


@pytest.mark.skipif(not HAVE_ROOFLINE, reason="benchmarks not importable")
def test_active_params_less_than_total_for_moe():
    for name in ("qwen3-moe-30b-a3b", "jamba-1.5-large-398b",
                 "moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b"):
        cfg = configs.get(name)
        assert active_param_count(cfg) < param_count(cfg)
    # qwen3: ~3B active of ~30B
    n_act = active_param_count(configs.get("qwen3-moe-30b-a3b"))
    assert 2e9 < n_act < 4.5e9
