"""Launch layer: HLO collective parser, sharding policy rules, mesh specs,
and one real (subprocess) dry-run combo on the production mesh."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# collective_bytes parser (pure text)
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %x = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %p), replica_groups={}
  %y = f32[16]{0} all-gather(f32[4]{0} %q), dimensions={0}
  %z.1 = (f32[32]{0}, u32[], u32[]) all-to-all-start(f32[32]{0} %r)
  %z.2 = f32[32]{0} all-to-all-done((f32[32],u32[],u32[]) %z.1)
  %w = f32[64]{0} add(f32[64]{0} %a, f32[64]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 8 * 128 * 2
    assert out["bytes"]["all-gather"] == 16 * 4
    # async pair counted once (start only)
    assert out["counts"]["all-to-all"] == 1
    assert out["bytes"]["collective-permute"] == 0


def test_bytes_of_shape_tuple():
    from repro.launch.dryrun import _bytes_of_shape
    assert _bytes_of_shape("bf16[2,3]") == 12
    assert _bytes_of_shape("(f32[4], u32[2])") == 16 + 8
    assert _bytes_of_shape("token[]") == 0


# ---------------------------------------------------------------------------
# sharding policy rules (no devices needed — pure spec logic)
# ---------------------------------------------------------------------------

class FakeMesh:
    axis_names = ("data", "model")
    devices = np.empty((16, 16), object)


def test_param_pspec_rules():
    from repro.launch.sharding import param_pspec
    mesh = FakeMesh()
    # embed vocab-sharded
    assert param_pspec("['embed']", (128256, 2048), mesh,
                       stacked_layers=True)[0] == "model"
    # attention in-proj shards output features; out-proj shards input
    p = param_pspec("['blocks'][0]['mixer']['wq']", (16, 2048, 2048), mesh,
                    stacked_layers=True)
    assert p[2] == "model" and p[0] is None
    p = param_pspec("['blocks'][0]['mixer']['wo']", (16, 2048, 2048), mesh,
                    stacked_layers=True)
    assert p[1] == "model"
    # experts shard the E dim
    p = param_pspec("['blocks'][0]['ffn']['w1']", (16, 128, 2048, 768), mesh,
                    stacked_layers=True)
    assert p[1] == "model" and p[0] is None
    # norms replicate
    p = param_pspec("['blocks'][0]['ln1']", (16, 2048), mesh,
                    stacked_layers=True)
    assert all(x is None for x in p)
    # GQA K/V policy (§Perf iter 3): replicate when a shard would hold less
    # than one whole head (1536/16 = 96 < 128) …
    p = param_pspec("['blocks'][0]['mixer']['wk']", (48, 1536, 1536), mesh,
                    stacked_layers=True)
    assert all(x is None for x in p)
    # … shard when every shard holds ≥ one whole head (2048/16 = 128)
    p = param_pspec("['blocks'][0]['mixer']['wk']", (48, 2048, 2048), mesh,
                    stacked_layers=True)
    assert p[2] == "model"


def test_param_pspec_fsdp_adds_data_axis():
    from repro.launch.sharding import param_pspec
    p = param_pspec("['blocks'][0]['ffn']['w1']", (36, 16, 8192, 24576),
                    FakeMesh(), stacked_layers=True, fsdp=True)
    assert "model" in p and "data" in p


# ---------------------------------------------------------------------------
# real dry-run in a subprocess (owns its 512 fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_subprocess_single_combo(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--out", str(tmp_path), "--no-probe"],
        capture_output=True, text=True, env=env, timeout=900, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(os.path.join(
        str(tmp_path), "xlstm-125m_decode_32k_16x16.json")))
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["cost_analysis"]["flops"] > 0


def test_input_specs_shapes_no_devices():
    """input_specs builds pure ShapeDtypeStructs — no allocation, any mesh."""
    import numpy as np
    from repro.launch.specs import input_specs

    # a fake 1-device mesh is enough for spec construction? No — sharding
    # needs real mesh axes; use the real 1-CPU device grid reshaped.
    # Instead assert the struct builder through a tiny real mesh is covered
    # by the subprocess test; here check the train batch struct helper.
    from repro import configs
    from repro.launch.specs import _train_batch_struct
    cfg = configs.get("llama3.2-1b")
    b = _train_batch_struct(cfg, K=16, B_per=16, S=4096)
    assert b["tokens"].shape == (16, 16, 4096)
    cfg2 = configs.get("musicgen-medium")
    b2 = _train_batch_struct(cfg2, K=16, B_per=16, S=4096)
    assert b2["embeds"].shape == (16, 16, 4096, 1536)
    assert b2["labels"].shape == (16, 16, 4096)
