"""End-to-end system behaviour: the paper's full pipeline — channel →
Algorithm 1 → async-FL protocol → energy/accuracy — plus CLI drivers and
checkpoint integration."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (GreedyScheme, ProposedOffline,
                                  ProposedOnline, RandomScheme)
from repro.data import make_cifar_like, make_mnist_like, shard_noniid
from repro.fl import SimConfig, run_simulation
from repro.models.small import (cnn_accuracy, cnn_loss, init_cnn, init_mlp,
                                mlp_accuracy, mlp_loss)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def world(rounds=10, K=10):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=3000, n_test=500)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=5)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    return tr, te, clients, cell, h


def test_e2e_proposed_beats_random_energy_at_matched_participation():
    """The paper's headline: for the same average participation, the
    proposed scheme spends less energy (channel-aware w + p)."""
    rounds = 12
    tr, te, clients, cell, h = world(rounds)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=rounds)
    cfg = SimConfig(rounds=rounds, local_iters=2, batch_size=10, eval_every=6)
    params = init_mlp(jax.random.PRNGKey(4))

    prop = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          ProposedOnline(spec), h, cell, cfg)
    from repro.core.selection import average_participants
    avg = average_participants(ProposedOnline(spec), h)
    rand = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          RandomScheme(min(avg / 10, 1.0), 10), h, cell, cfg)
    # matched participation, less energy, comparable-or-better accuracy
    assert prop.energy_per_client.sum() < rand.energy_per_client.sum() * 1.05
    assert prop.test_acc[-1] > 0.1  # learning happened


def test_e2e_offline_policy_runs():
    """Algorithm 1 (offline) drives the simulator end to end."""
    rounds = 8
    tr, te, clients, cell, h = world(rounds)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=rounds)
    cfg = SimConfig(rounds=rounds, local_iters=1, batch_size=8, eval_every=4)
    params = init_mlp(jax.random.PRNGKey(4))
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         ProposedOffline(spec, h), h, cell, cfg)
    assert np.isfinite(res.test_acc).all()
    assert res.energy_per_client.sum() > 0


def test_e2e_cnn_cifar_like():
    """The paper's second task family (CIFAR/conv net) trains."""
    tr, te = make_cifar_like(jax.random.PRNGKey(0), n_train=800, n_test=200)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, 10, d=5)
    cell = CellConfig(num_clients=10)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, 4).T
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=4)
    params = init_cnn(jax.random.PRNGKey(4), widths=(8, 16), fc=32)
    cfg = SimConfig(rounds=4, local_iters=1, batch_size=16, eval_every=3,
                    eval_batch=200)
    res = run_simulation(params, cnn_loss, cnn_accuracy, clients, te,
                         ProposedOnline(spec), h, cell, cfg)
    assert np.isfinite(res.test_loss).all()


def test_e2e_checkpoint_resume():
    rounds = 4
    tr, te, clients, cell, h = world(rounds)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=rounds)
    cfg = SimConfig(rounds=rounds, local_iters=1, batch_size=8, eval_every=2)
    params = init_mlp(jax.random.PRNGKey(4))
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         ProposedOnline(spec), h, cell, cfg)
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck")
        save_checkpoint(path, res.state.global_params, {"round": rounds})
        restored, meta = load_checkpoint(path, params)
        assert meta["round"] == rounds
        a1 = float(mlp_accuracy(res.state.global_params, te.x[:200],
                                te.y[:200]))
        a2 = float(mlp_accuracy(restored, te.x[:200], te.y[:200]))
        assert np.isclose(a1, a2, atol=1e-6)


@pytest.mark.slow
def test_train_cli_paper_mode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--rounds", "4",
         "--train-examples", "1000", "--local-iters", "1"],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "final_acc" in out.stdout


@pytest.mark.slow
def test_serve_cli_reduced_arch():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "xlstm-125m",
         "--reduced", "--batch", "2", "--new-tokens", "4"],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "decode" in out.stdout
