"""Device-resident data store: packing, on-device sampling, jittable
partitioners, engine parity across the three data paths, and the
no-T-proportional-buffer guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig
from repro.core.selection import RandomScheme, as_policy_fn
from repro.data import (Dataset, DeviceDataStore, StreamingSampler,
                        choose_data_path, data_stream_key, dirichlet_store,
                        from_client_datasets, label_histogram, make_mnist_like,
                        round_indices, sample_round, shard_noniid, shard_store,
                        stack_rounds_reference)
from repro.fl import SimConfig, build_scan_sim, make_runner, run_simulation
from repro.fl.simulator import run_simulation_legacy
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss
from repro.optim import sgd


def small_world(K=8, rounds=12, dim=64, n_train=1200, d=5):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=n_train,
                             n_test=300)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=d)
    clients = [Dataset(c.x[:, :dim], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    from repro.core.channel import channel_gains, sample_positions
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 24, 10))
    return clients, te, cell, h, params


# --- store packing + sampling ----------------------------------------------


def test_store_packing_and_masks():
    clients = [Dataset(jnp.ones((n, 3)) * (i + 1.0),
                       jnp.full((n,), i, jnp.int32), 4)
               for i, n in enumerate((5, 9, 7))]
    store = from_client_datasets(clients)
    assert store.x.shape == (3, 9, 3) and store.y.shape == (3, 9)
    assert store.lengths.tolist() == [5, 9, 7]
    # padding rows are zero
    assert float(jnp.abs(store.x[0, 5:]).max()) == 0.0
    # sampled indices never reach the padding
    idx = round_indices(data_stream_key(0), jnp.int32(7), store.lengths,
                        local_iters=4, batch_size=16)
    assert idx.shape == (3, 4, 16)
    assert bool(jnp.all(idx < store.lengths[:, None, None]))
    xb, yb = sample_round(store, data_stream_key(0), jnp.int32(7), 4, 16)
    # every drawn row belongs to its client (client i holds value i+1/label i)
    for k in range(3):
        assert float(jnp.abs(xb[k] - (k + 1.0)).max()) == 0.0
        assert yb[k].min() == k and yb[k].max() == k


def test_store_rejects_empty_client():
    clients = [Dataset(jnp.ones((4, 2)), jnp.zeros((4,), jnp.int32), 2),
               Dataset(jnp.ones((0, 2)), jnp.zeros((0,), jnp.int32), 2)]
    with pytest.raises(ValueError, match="non-empty"):
        from_client_datasets(clients)


def test_stream_depends_only_on_key_and_round():
    lengths = jnp.array([10, 20], jnp.int32)
    a = round_indices(data_stream_key(3), jnp.int32(5), lengths, 2, 4)
    b = round_indices(data_stream_key(3), jnp.int32(5), lengths, 2, 4)
    c = round_indices(data_stream_key(3), jnp.int32(6), lengths, 2, 4)
    d = round_indices(data_stream_key(4), jnp.int32(5), lengths, 2, 4)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert not np.array_equal(np.asarray(a), np.asarray(d))


# --- engine parity: device path == pre-stacked reference, bit-identical -----


def test_device_sampler_matches_prestacked_reference_T50():
    """The tentpole parity claim: the in-scan sampler and the [T, K, L, B]
    pre-stack of the *same* fold_in stream produce bit-identical loss /
    energy trajectories at T=50."""
    T = 50
    clients, te, cell, h, params = small_world(rounds=T)
    cfg = SimConfig(rounds=T, local_iters=2, batch_size=8, eval_every=10,
                    eval_batch=200, data_path="device")
    policy = RandomScheme(p_bar=0.3, num_clients=8)
    runner = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                         cfg)
    res_dev = runner(params, h)

    # same stream, materialized eagerly into the legacy layout
    store = from_client_datasets(clients)
    xb_all, yb_all = stack_rounds_reference(store, data_stream_key(cfg.seed),
                                            T, cfg.local_iters,
                                            cfg.batch_size)
    sim = build_scan_sim(mlp_loss, mlp_accuracy, sgd(cfg.lr), cfg, cell, 8,
                         as_policy_fn(policy), shard_clients=False,
                         data_mode="prestack")
    state, energy, traces = jax.jit(sim)(
        params, xb_all, yb_all, jnp.swapaxes(h, 0, 1),
        jax.random.PRNGKey(cfg.seed), te.x[:200], te.y[:200])

    did = np.asarray(traces.did_eval)
    idx = np.where(did)[0]
    assert np.array_equal(res_dev.test_loss, np.asarray(traces.loss)[idx])
    assert np.array_equal(res_dev.test_acc, np.asarray(traces.acc)[idx])
    assert np.array_equal(res_dev.energy_per_client, np.asarray(energy))
    for a, b in zip(jax.tree_util.tree_leaves(res_dev.state.global_params),
                    jax.tree_util.tree_leaves(state.global_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_scan_vs_legacy_parity_on_device_path():
    clients, te, cell, h, params = small_world(rounds=10)
    cfg = SimConfig(rounds=10, local_iters=2, batch_size=8, eval_every=4,
                    eval_batch=200, data_path="device")
    policy = RandomScheme(p_bar=0.4, num_clients=8)
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, policy, h, cell, cfg)
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_allclose(scan.test_loss, legacy.test_loss, atol=1e-5)
    np.testing.assert_allclose(scan.energy_per_client,
                               legacy.energy_per_client, rtol=1e-6)


def test_prestack_path_still_parity_checked():
    """The legacy BatchIterator pre-stack stays available and bit-equal
    across engines when forced via cfg.data_path."""
    clients, te, cell, h, params = small_world(rounds=8)
    cfg = SimConfig(rounds=8, local_iters=2, batch_size=8, eval_every=3,
                    eval_batch=200, data_path="prestack")
    policy = RandomScheme(p_bar=0.4, num_clients=8)
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, policy, h, cell, cfg)
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_allclose(scan.test_loss, legacy.test_loss, atol=1e-5)


def test_streaming_fallback_bit_identical_to_device_path():
    """Chunked host streaming (double-buffered prefetch) replays the same
    stream: results match the on-device path bit-wise across chunk
    boundaries (T=20, chunk=7 → 3 uneven chunks)."""
    T = 20
    clients, te, cell, h, params = small_world(rounds=T)
    base = dict(rounds=T, local_iters=2, batch_size=8, eval_every=6,
                eval_batch=200)
    policy = RandomScheme(p_bar=0.3, num_clients=8)
    dev = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                      SimConfig(**base, data_path="device"))(params, h)
    stream = make_runner(mlp_loss, mlp_accuracy, clients, te, policy, cell,
                         SimConfig(**base, data_path="stream",
                                   stream_chunk=7))(params, h)
    assert np.array_equal(dev.participation, stream.participation)
    assert np.array_equal(dev.test_loss, stream.test_loss)
    assert np.array_equal(dev.test_acc, stream.test_acc)
    # energy crosses two differently-fused XLA programs → ULP-level slack
    np.testing.assert_allclose(dev.energy_per_client,
                               stream.energy_per_client, rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(dev.state.global_params),
                    jax.tree_util.tree_leaves(stream.state.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_legacy_loop_stream_path_stays_host_side_and_matches():
    """When the resolver picks "stream" the legacy host loop must serve
    batches from host memory (one-round chunks of the same index stream),
    not materialize the device store — and still match the chunked scan
    engine."""
    T = 10
    clients, te, cell, h, params = small_world(rounds=T)
    cfg = SimConfig(rounds=T, local_iters=2, batch_size=8, eval_every=4,
                    eval_batch=200, data_path="stream", stream_chunk=4)
    policy = RandomScheme(p_bar=0.4, num_clients=8)
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, policy, h, cell, cfg)
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_allclose(scan.test_loss, legacy.test_loss, atol=1e-5)
    np.testing.assert_allclose(scan.energy_per_client,
                               legacy.energy_per_client, rtol=1e-6)


# --- memory: no T-proportional buffer on the device path --------------------


def _all_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out.append(v.aval)
        for p in eqn.params.values():
            for j in jax.tree_util.tree_leaves(
                    p, is_leaf=lambda x: hasattr(x, "jaxpr") or
                    hasattr(x, "eqns")):
                inner = getattr(j, "jaxpr", j)
                if hasattr(inner, "eqns"):
                    _all_avals(inner, out)
    return out


def _max_var_elems(closed):
    avals = [v.aval for v in closed.jaxpr.invars]
    _all_avals(closed.jaxpr, avals)
    return max(int(np.prod(a.shape)) for a in avals if hasattr(a, "shape")
               and a.shape)


def test_no_T_proportional_buffer_at_T2000():
    """jaxpr allocation check at (T=2000, K=16, MNIST-scale): the largest
    array anywhere in the device-path program must stay far below the
    [T, K, L, B, 784] pre-stack; the prestack-mode program (the reference)
    must contain exactly that buffer."""
    T, K, L, B, dim = 2000, 16, 5, 10, 784
    cap = 500
    cfg = SimConfig(rounds=T, local_iters=L, batch_size=B, eval_every=100,
                    eval_batch=256, data_path="device")
    cell = CellConfig(num_clients=K)
    params = init_mlp(jax.random.PRNGKey(0), dims=(dim, 200, 10))
    policy_fn = as_policy_fn(RandomScheme(p_bar=0.2, num_clients=K))
    store = DeviceDataStore(
        jax.ShapeDtypeStruct((K, cap, dim), jnp.float32),
        jax.ShapeDtypeStruct((K, cap), jnp.int32),
        jax.ShapeDtypeStruct((K,), jnp.int32))
    args = (params, store, jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct((T, K), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            jax.ShapeDtypeStruct((256, dim), jnp.float32),
            jax.ShapeDtypeStruct((256,), jnp.int32))
    opt = sgd(cfg.lr)

    sim_dev = build_scan_sim(mlp_loss, mlp_accuracy, opt, cfg, cell, K,
                             policy_fn, shard_clients=False,
                             data_mode="device")
    dev_max = _max_var_elems(jax.make_jaxpr(sim_dev)(*args))

    prestack_elems = T * K * L * B * dim
    # device path: largest live array ≪ the pre-stack (store + test set + a
    # handful of [K, L, B, dim] round batches are the biggest things left)
    assert dev_max < prestack_elems // 20, (dev_max, prestack_elems)

    # the reference path really does carry the [T, K, L, B, dim] buffer —
    # the check above is meaningful
    sim_pre = build_scan_sim(mlp_loss, mlp_accuracy, opt, cfg, cell, K,
                             policy_fn, shard_clients=False,
                             data_mode="prestack")
    pre_args = (params,
                jax.ShapeDtypeStruct((T, K, L, B, dim), jnp.float32),
                jax.ShapeDtypeStruct((T, K, L, B), jnp.int32)) + args[3:]
    pre_max = _max_var_elems(jax.make_jaxpr(sim_pre)(*pre_args))
    assert pre_max >= prestack_elems


# --- jittable partitioners --------------------------------------------------


def test_shard_store_properties():
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=2000, n_test=100)
    for d in (2, 5, 10):
        st = shard_store(jax.random.PRNGKey(1), tr, 10, d=d)
        hist = np.asarray(label_histogram(st, 10))
        assert int(st.lengths.sum()) == 2000          # every example kept
        assert (hist.sum(1) == np.asarray(st.lengths)).all()
        assert ((hist > 0).sum(1) <= d).all()         # ≤ d labels per client


def test_shard_store_heterogeneity_monotone():
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=3000, n_test=100)

    def tv(st):
        p = np.asarray(label_histogram(st, 10)).astype(float)
        p /= np.maximum(p.sum(1, keepdims=True), 1)
        return np.mean([0.5 * np.abs(p[i] - p[j]).sum()
                        for i in range(10) for j in range(i + 1, 10)])

    het = [tv(shard_store(jax.random.PRNGKey(1), tr, 10, d=d))
           for d in (2, 5, 10)]
    assert het[0] > het[1] > het[2]


def test_dirichlet_store_alpha_controls_heterogeneity():
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=3000, n_test=100)
    lo = dirichlet_store(jax.random.PRNGKey(2), tr, 10, alpha=0.05)
    hi = dirichlet_store(jax.random.PRNGKey(2), tr, 10, alpha=100.0)
    assert int(lo.lengths.sum()) == 3000 and int(hi.lengths.sum()) == 3000
    n_lo = (np.asarray(label_histogram(lo, 10)) > 0).sum(1).mean()
    n_hi = (np.asarray(label_histogram(hi, 10)) > 0).sum(1).mean()
    assert n_lo < n_hi                  # small α ⇒ fewer classes per client
    assert n_hi > 9.0                   # large α ⇒ IID-like


def test_partitioner_rejects_zero_example_client():
    """Host entries (cap=None) refuse degenerate partitions — a zero-length
    client would otherwise silently sample padding forever."""
    ds = Dataset(jnp.ones((5, 4)), jnp.arange(5, dtype=jnp.int32) % 10, 10)
    with pytest.raises(ValueError, match="no examples"):  # 5 < K=10
        dirichlet_store(jax.random.PRNGKey(0), ds, 10, alpha=1.0)


def test_partitioners_vmap_over_lane_keys():
    """Per-scenario-lane non-IID realizations in one device program: both
    partitioners vmap over the key with a static capacity."""
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=1000, n_test=100)
    keys = jax.random.split(jax.random.PRNGKey(9), 4)
    sh = jax.vmap(lambda k: shard_store(k, tr, 5, d=2, cap=420))(keys)
    di = jax.vmap(lambda k: dirichlet_store(k, tr, 5, 0.3, cap=1000))(keys)
    assert sh.x.shape == (4, 5, 420, 784) and di.x.shape == (4, 5, 1000, 784)
    assert (np.asarray(sh.lengths.sum(axis=1)) <= 1000).all()
    assert (np.asarray(di.lengths.sum(axis=1)) == 1000).all()
    # lanes differ (different keys ⇒ different partitions)
    assert not np.array_equal(np.asarray(di.lengths[0]),
                              np.asarray(di.lengths[1]))


# --- mesh placement ---------------------------------------------------------


def test_client_axis_shardings_specs():
    """Store leaves map their leading K axis onto the client mesh axis;
    non-divisible leaves replicate (divisibility guard)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.launch.sharding import client_axis_shardings
    mesh = Mesh(np.array(jax.devices()[:1]), ("k",))
    clients = [Dataset(jnp.ones((4, 3)), jnp.zeros((4,), jnp.int32), 2)
               for _ in range(3)]
    sh = client_axis_shardings(from_client_datasets(clients), mesh, "k")
    assert sh.x.spec == P("k", None, None)
    assert sh.y.spec == P("k", None)
    assert sh.lengths.spec == P("k")
    # a scalar-leaf tree replicates
    rep = client_axis_shardings({"s": jnp.zeros(())}, mesh, "k")
    assert rep["s"].spec == P()


# --- footprint planner + streaming sampler ----------------------------------


def test_choose_data_path_by_footprint():
    clients = [Dataset(jnp.ones((50, 8)), jnp.zeros((50,), jnp.int32), 10)
               for _ in range(4)]
    assert choose_data_path(clients, budget_bytes=1 << 30) == "device"
    assert choose_data_path(clients, budget_bytes=1_000) == "stream"


def test_streaming_sampler_matches_reference():
    clients, te, cell, h, params = small_world(rounds=6)
    dk = data_stream_key(0)
    store = from_client_datasets(clients)
    ref_x, ref_y = stack_rounds_reference(store, dk, 6, 2, 8)
    ss = StreamingSampler(clients, dk, 2, 8)
    cx, cy = ss.chunk(2, 5)
    assert np.array_equal(np.asarray(cx), np.asarray(ref_x[2:5]))
    assert np.array_equal(np.asarray(cy), np.asarray(ref_y[2:5]))
