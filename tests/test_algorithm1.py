"""Algorithm 1 (offline) and the online variant: feasibility, KKT residuals,
global optimality vs brute force on small instances, and the paper's
qualitative insights (Lemmas 2-3)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import CellConfig, ProblemSpec
from repro.core import algorithm1 as a1
from repro.core.channel import channel_gains, sample_positions, rate_nats
from repro.core.online import objective_p1_prime, solve_online


def make_instance(seed=0, K=10, T=20, rho=0.05, lam=0.01):
    cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=cell, rho=rho, lam=lam, num_rounds=T)
    pos = sample_positions(jax.random.PRNGKey(seed), cell)
    h = channel_gains(jax.random.PRNGKey(seed + 1), pos, T).T  # [K, T]
    return spec, h


def test_offline_feasible_and_converged():
    spec, h = make_instance()
    res = a1.solve(h, spec)
    p, w = np.asarray(res.p), np.asarray(res.w)
    assert p.shape == (spec.K, spec.T) and w.shape == (spec.K, spec.T)
    assert np.all(p >= spec.lam - 1e-6) and np.all(p <= 1.0 + 1e-6)
    assert np.all(w >= 0.0) and np.all(w.sum(axis=0) <= 1.0 + 1e-4)
    assert float(res.residual) < 1e-6
    assert np.isfinite(float(res.objective))


def test_offline_beats_naive_allocations():
    spec, h = make_instance()
    res = a1.solve(h, spec)
    K, T = spec.K, spec.T
    for p_const in (0.05, 0.1, 0.3, 0.7, 1.0):
        p = jnp.full((K, T), p_const)
        w = jnp.full((K, T), 1.0 / K)
        naive = float(a1.objective_p1(p, w, h, spec))
        assert float(res.objective) <= naive * 1.001, p_const


def _grid_best(spec, h_pair, objective):
    """Vectorized exhaustive grid over (p1, p2, w1) for a K=2 instance."""
    ps = jnp.linspace(spec.lam, 1.0, 61)
    ws = jnp.linspace(1e-3, 1.0 - 1e-3, 121)
    P1, P2, W1 = jnp.meshgrid(ps, ps, ws, indexing="ij")
    flat = jax.jit(jax.vmap(lambda p1, p2, w1: objective(
        jnp.stack([p1, p2])[:, None], jnp.stack([w1, 1.0 - w1])[:, None],
        h_pair, spec)))
    objs = flat(P1.ravel(), P2.ravel(), W1.ravel())
    return float(jnp.min(objs))


def test_offline_matches_bruteforce_small():
    """K=2, T=1: exhaustive grid over (p1, p2, w1) — the solver must match the
    global optimum of (P1) within grid resolution."""
    cell = CellConfig(num_clients=2)
    spec = ProblemSpec(cell=cell, rho=0.2, lam=0.01, num_rounds=1)
    h = jnp.array([[3e-13], [4e-14]])
    res = a1.solve(h, spec)
    best = _grid_best(spec, h, a1.objective_p1)
    assert float(res.objective) <= best * 1.02 + 1e-6


def test_online_feasible_and_converged():
    spec, h = make_instance()
    res = solve_online(h[:, 0], spec)
    p, w = np.asarray(res.p), np.asarray(res.w)
    assert np.all(p >= spec.lam - 1e-6) and np.all(p <= 1.0 + 1e-6)
    assert np.all(w >= 0.0) and float(w.sum()) <= 1.0 + 1e-3
    assert float(res.residual) < 1e-6


def test_online_matches_bruteforce_small():
    cell = CellConfig(num_clients=2)
    spec = ProblemSpec(cell=cell, rho=0.2, lam=0.01, num_rounds=10)
    h = jnp.array([3e-13, 4e-14])
    res = solve_online(h, spec)
    best = _grid_best(
        spec, h,
        lambda p, w, hh, sp: objective_p1_prime(p[:, 0], w[:, 0], hh, sp))
    assert float(res.objective) <= best * 1.02 + 1e-6


def test_channel_aware_participation():
    """Better channels ⇒ (weakly) higher selection probability — the
    multi-user-diversity insight behind individual Δ_k."""
    cell = CellConfig(num_clients=8)
    spec = ProblemSpec(cell=cell, rho=0.05, lam=0.01, num_rounds=10)
    h = jnp.logspace(-15, -11, 8)  # strictly increasing gains
    res = solve_online(h, spec)
    p = np.asarray(res.p)
    # top-gain client participates at least as much as bottom-gain client
    assert p[-1] >= p[0] - 1e-4
    # rank correlation positive
    corr = np.corrcoef(np.arange(8), p)[0, 1]
    assert corr > 0.5


def test_rho_tradeoff_lemma2():
    """Larger ρ (convergence-focused) ⇒ more participation & more energy;
    Lemma 2: more communication improves the convergence metric."""
    spec_lo, h = make_instance(rho=0.01)
    spec_hi, _ = make_instance(rho=0.3)
    r_lo = a1.solve(h, spec_lo)
    r_hi = a1.solve(h, spec_hi)
    sum_lo, sum_hi = float(r_lo.p.sum()), float(r_hi.p.sum())
    assert sum_hi > sum_lo
    from repro.core.convergence import convergence_metric
    assert float(convergence_metric(r_hi.p)) < float(convergence_metric(r_lo.p))


def test_p4_bisection_matches_subgradient():
    """The bisection dual search and the paper's subgradient loop (33) find
    the same bandwidth allocation."""
    cell = CellConfig(num_clients=6)
    key = jax.random.PRNGKey(3)
    ab = jnp.abs(jax.random.normal(key, (6,))) * 1e-7 + 1e-8
    h = jnp.logspace(-14, -12, 6)
    w_b = np.asarray(a1.solve_p4(ab, h, cell))
    w_s = np.asarray(a1.solve_p4_subgradient(ab, h, cell, iters=4000))
    # subgradient converges slowly; match within a loose tolerance
    assert np.allclose(w_b, w_s, atol=0.05)


@pytest.mark.parametrize("rho,expect", [(0.0, "lam"), (1.0, "one")])
def test_online_boundary_rho_finite_and_clipped(rho, expect):
    """ρ = 0 kills the convergence term (p collapses to the floor λ);
    ρ = 1 kills the energy term (p saturates at 1). Both endpoints used to
    divide by (1 − ρ) and emit NaN — now they return finite clipped p."""
    spec, h = make_instance(rho=rho)
    res = solve_online(h[:, 0], spec)
    p = np.asarray(res.p)
    assert np.isfinite(p).all()
    want = spec.lam if expect == "lam" else 1.0
    np.testing.assert_allclose(p, want, atol=1e-4)


def test_online_vmappable_over_rho_grid_with_endpoints():
    """The hardened solver stays finite under vmap across a ρ grid that
    includes both degenerate endpoints — the fault-matrix sweep relies on
    this shape of batching."""
    cell = CellConfig(num_clients=6)
    spec = ProblemSpec(cell=cell, rho=0.5, lam=0.05, num_rounds=20)
    pos = sample_positions(jax.random.PRNGKey(5), cell)
    h = channel_gains(jax.random.PRNGKey(6), pos, 1).T[:, 0]

    rhos = jnp.array([0.0, 0.25, 0.5, 0.75, 1.0])
    ps = jax.vmap(lambda r: solve_online(h, spec, rho=r).p)(rhos)
    ps = np.asarray(ps)
    assert np.isfinite(ps).all()
    assert np.all(ps >= 0.05 - 1e-5) and np.all(ps <= 1.0 + 1e-5)


def test_online_alpha_floor_does_not_blow_up():
    """Near-zero effective step/α regimes (tiny λ, tiny gains) must keep the
    closed-form p* denominator off zero: probabilities stay in [λ, 1]."""
    cell = CellConfig(num_clients=4)
    spec = ProblemSpec(cell=cell, rho=0.5, lam=1e-6, num_rounds=5)
    h = jnp.full((4,), 1e-20)  # pathologically weak channels
    res = solve_online(h, spec)
    p = np.asarray(res.p)
    assert np.isfinite(p).all()
    assert np.all(p >= spec.lam - 1e-9) and np.all(p <= 1.0 + 1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.floats(min_value=0.01, max_value=0.5))
def test_property_feasibility_random_instances(seed, rho):
    spec, h = make_instance(seed=seed, K=5, T=6, rho=rho)
    res = a1.solve(h, spec, max_outer=300)
    p, w = np.asarray(res.p), np.asarray(res.w)
    assert np.all(p >= spec.lam - 1e-5) and np.all(p <= 1.0 + 1e-5)
    assert np.all(w >= 0) and np.all(w.sum(0) <= 1.0 + 1e-3)
    assert np.isfinite(float(res.objective))
