"""Roofline analysis unit tests: term math, probe reconstruction, MoE active
params, and consistency against the shipped artifacts when present."""
import glob
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.roofline import active_param_count, analyze, model_flops  # noqa: E402
from repro import configs  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402


def fake_record(flops=1e12, bytes_=1e11, coll=1e9, arch="llama3.2-1b",
                shape="train_4k"):
    return {
        "status": "ok", "arch": arch, "shape": shape, "mesh": "16x16",
        "mode": "replica", "devices": 256,
        "memory_analysis": {"argument_size_in_bytes": 1, "temp_size_in_bytes": 1},
        "cost_probe": {
            "total": {"flops": flops, "bytes": bytes_,
                      "collective_bytes": coll},
            "m1": {"collectives": {"total_bytes": coll / 2}},
        },
    }


def test_terms_formulae():
    r = analyze(fake_record())
    assert abs(r["t_compute_s"] - 1e12 / PEAK_FLOPS_BF16) < 1e-12
    assert abs(r["t_memory_s"] - 1e11 / HBM_BW) < 1e-12
    assert abs(r["t_collective_s"] - 1e9 / ICI_BW) < 1e-12
    assert r["dominant"] in ("compute", "memory", "collective")


def test_negative_collective_clamped_to_m1():
    r = analyze(fake_record(coll=-5.0))
    assert r["coll_bytes_per_dev"] == -2.5  # m1 floor (coll/2)


def test_dominant_selection():
    r = analyze(fake_record(flops=1e18, bytes_=1, coll=1))
    assert r["dominant"] == "compute"
    r = analyze(fake_record(flops=1, bytes_=1e15, coll=1))
    assert r["dominant"] == "memory"


def test_model_flops_kinds():
    cfg = configs.get("llama3.2-1b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert t > p > d
    # decode = 2·N·B exactly
    n = active_param_count(cfg)
    assert abs(d - 2.0 * n * 128) / d < 1e-9


def test_probe_reconstruction_identity():
    """M(R) = M1 + (R−1)(M2−M1) is exact for any linear-in-R metric."""
    import random
    random.seed(0)
    for _ in range(20):
        per_sb = random.uniform(1, 100)
        fixed = random.uniform(1, 100)
        R = random.randint(1, 72)
        m1 = fixed + per_sb
        m2 = fixed + 2 * per_sb
        assert abs((m1 + (R - 1) * (m2 - m1)) - (fixed + R * per_sb)) < 1e-9


@pytest.mark.skipif(not glob.glob(os.path.join(REPO, "artifacts/dryrun",
                                               "*_16x16.json")),
                    reason="no dry-run artifacts in tree")
def test_artifacts_complete_and_ok():
    """The shipped baseline artifacts cover all 40 pairs, all OK."""
    recs = [json.load(open(p)) for p in
            glob.glob(os.path.join(REPO, "artifacts/dryrun", "*_16x16.json"))]
    pairs = {(r["arch"], r["shape"]) for r in recs}
    assert len(pairs) == 40
    assert all(r["status"] == "ok" for r in recs)
    mp = [json.load(open(p)) for p in
          glob.glob(os.path.join(REPO, "artifacts/dryrun", "*_2x16x16.json"))]
    assert len(mp) == 40 and all(r["status"] == "ok" for r in mp)
    # every record that has probes reconstructs positive flops
    for r in recs:
        if "cost_probe" in r:
            assert r["cost_probe"]["total"]["flops"] > 0
