"""Observability layer: in-scan metrics taps (bit-parity when disabled,
cross-path agreement when enabled, no extra carry buffers in the untapped
jaxpr), host telemetry manifests, the benchmark reporter's regression
gate, and the resumable driver's segment manifest."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig
from repro.core.selection import RandomScheme, as_policy_fn, csma_policy
from repro.data.device import data_stream_key, from_client_datasets
from repro.fl import (FaultConfig, GuardConfig, SimConfig, make_sparse_runner,
                      run_fault_matrix, run_simulation, run_simulation_legacy)
from repro.fl.engine import build_scan_sim, init_carry
from repro.fl.resume import read_segment_manifest, run_resumable
from repro.fl.schemes import run_scheme_matrix
from repro.models.small import mlp_accuracy, mlp_loss
from repro.obs import (MetricsSpec, metrics_summary, timed_compile,
                       validate_manifest)
from repro.obs import report as obs_report
from repro.obs.telemetry import emit_run_manifest, get_telemetry
from repro.optim import sgd

from test_engine_parity import tiny_world
from test_scheme_parity import _matrix_world, _panel, sparse_cfg

K, T = 5, 8


def _cfg(**kw):
    base = dict(rounds=T, local_iters=2, batch_size=4, eval_every=2,
                local_mode="participants", data_path="device",
                data_stream="client")
    base.update(kw)
    return SimConfig(**base)


def _run_dense(cfg, policy=None):
    clients, te, cell, h, params = tiny_world(K=K, rounds=T, dim=32)
    policy = policy or csma_policy(3, K)
    return run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)


def assert_metrics_agree(a, b, err=""):
    """Integer taps bit-exact; float taps to float-associativity tolerance."""
    assert a is not None and b is not None
    for f in type(a)._fields:
        va, vb = getattr(a, f), getattr(b, f)
        if va is None:
            assert vb is None, f"{err}: {f} active on one path only"
            continue
        va, vb = np.asarray(va), np.asarray(vb)
        if np.issubdtype(va.dtype, np.integer):
            np.testing.assert_array_equal(va, vb, err_msg=f"{err}: {f}")
        else:
            np.testing.assert_allclose(va, vb, rtol=1e-5, atol=1e-6,
                                       err_msg=f"{err}: {f}")


# --- disabled taps: bit parity and no extra carry ---------------------------


def test_disabled_taps_bit_parity_dense():
    off = _run_dense(_cfg(metrics=None))
    none = _run_dense(_cfg(metrics=MetricsSpec.none()))
    assert off.metrics is None and none.metrics is None
    np.testing.assert_array_equal(off.participation, none.participation)
    np.testing.assert_array_equal(np.asarray(off.test_acc),
                                  np.asarray(none.test_acc))
    np.testing.assert_array_equal(np.asarray(off.energy_per_client),
                                  np.asarray(none.energy_per_client))


def test_tapped_run_does_not_perturb_trajectory():
    off = _run_dense(_cfg(metrics=None))
    on = _run_dense(_cfg(metrics=MetricsSpec()))
    np.testing.assert_array_equal(off.participation, on.participation)
    np.testing.assert_array_equal(np.asarray(off.test_acc),
                                  np.asarray(on.test_acc))
    np.testing.assert_array_equal(np.asarray(off.energy_per_client),
                                  np.asarray(on.energy_per_client))
    assert off.metrics is None and on.metrics is not None


def test_disabled_taps_identical_jaxpr_and_carry():
    """MetricsSpec.none() must build the byte-identical program to
    metrics=None: no extra carry buffers, no extra ops."""
    clients, te, cell, h, params = tiny_world(K=K, rounds=T, dim=32)
    store = from_client_datasets(clients)
    data_key = data_stream_key(0)
    h_rounds = jnp.swapaxes(h, 0, 1)
    key = jax.random.PRNGKey(0)
    jaxprs, carries = [], []
    for spec in (None, MetricsSpec.none()):
        cfg = _cfg(metrics=spec)
        carries.append(init_carry(params, K, cfg))
        sim = build_scan_sim(mlp_loss, mlp_accuracy, sgd(cfg.lr), cfg, cell,
                             K, as_policy_fn(csma_policy(3, K)),
                             data_mode="device")
        jaxprs.append(str(jax.make_jaxpr(sim)(
            params, store, data_key, h_rounds, key,
            te.x[: cfg.eval_batch], te.y[: cfg.eval_batch])))
    assert (jax.tree_util.tree_structure(carries[0])
            == jax.tree_util.tree_structure(carries[1]))
    # identical up to the memory addresses repr'd into closure names
    import re
    norm = [re.sub(r"0x[0-9a-f]+", "0x", j) for j in jaxprs]
    assert norm[0] == norm[1]


# --- enabled taps: three-path agreement -------------------------------------


def test_taps_agree_across_all_three_paths():
    cfg = _cfg(metrics=MetricsSpec())
    clients, te, cell, h, params = tiny_world(K=K, rounds=T, dim=32)
    pol = csma_policy(3, K)
    dense = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                           pol, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, pol, h, cell, cfg)
    sp = make_sparse_runner(mlp_loss, mlp_accuracy, clients, te, pol,
                            cell, cfg)(params, h)
    assert_metrics_agree(dense.metrics, legacy.metrics, "dense-legacy")
    assert_metrics_agree(dense.metrics, sp.metrics, "dense-sparse")
    # internal consistency against the realized masks
    ms = dense.metrics
    np.testing.assert_array_equal(np.asarray(ms.tx_count),
                                  dense.participation.sum(axis=0))
    assert int(np.asarray(ms.rounds)) == T
    assert int(np.asarray(ms.stale_hist).sum()) == \
        int(dense.participation.sum())
    summ = metrics_summary(ms)
    assert summ["tx_total"] == int(dense.participation.sum())


def test_partial_spec_subsets_are_jittable():
    spec = MetricsSpec(participation=True, staleness_hist=False,
                       energy_by_cause=False, guard_events=False,
                       weight_stats=False)
    res = _run_dense(_cfg(metrics=spec))
    ms = res.metrics
    assert ms.tx_count is not None and ms.stale_hist is None
    assert ms.energy_cause is None and ms.weight_entropy is None
    np.testing.assert_array_equal(np.asarray(ms.tx_count),
                                  res.participation.sum(axis=0))


def test_guard_event_taps_count_quarantines():
    faults = FaultConfig(p_corrupt=0.5, corrupt_mode="nan")
    guards = GuardConfig(quarantine=True, clip_norm=10.0)
    cfg = _cfg(metrics=MetricsSpec(), faults=faults, guards=guards,
               participation="dense")
    res = _run_dense(cfg, policy=RandomScheme(p_bar=0.6, num_clients=K))
    ge = np.asarray(res.metrics.guard_events)
    assert ge.shape == (3,)
    assert ge[0] >= 1      # quarantined NaN updates counted


# --- matrix fan-outs under vmap ---------------------------------------------


def test_scheme_matrix_taps_dense_sparse_agree():
    _, stores, te, cell, h_stack, params = _matrix_world()
    cfg = sparse_cfg(metrics=MetricsSpec())
    seeds = [0, 1]
    dense = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                              _panel(), h_stack, cell, cfg, seeds,
                              participation="dense")
    sparse = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                               _panel(), h_stack, cell, cfg, seeds,
                               participation="sparse")
    assert dense.metrics is not None and sparse.metrics is not None
    # vmap axes [V severities, L schemes, S seeds] land on every tap
    assert np.asarray(dense.metrics.tx_count).shape == (2, 4, 2, K)
    assert_metrics_agree(dense.metrics, sparse.metrics, "matrix")
    np.testing.assert_array_equal(
        np.asarray(dense.metrics.tx_count),
        dense.participation.sum(axis=3))       # [V, L, S, T, K] -> per-client
    untapped = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                                 _panel(), h_stack, cell, sparse_cfg(), seeds,
                                 participation="dense")
    assert untapped.metrics is None
    np.testing.assert_array_equal(untapped.participation, dense.participation)


def test_fault_matrix_taps_per_guard_setting():
    clients, te, cell, h, params = tiny_world(K=K, rounds=T)
    faults = FaultConfig(p_loss=0.3, max_retries=1, p_corrupt=0.3,
                         corrupt_mode="nan")
    cfg = SimConfig(rounds=T, local_iters=1, batch_size=8, eval_every=4,
                    eval_batch=200, data_path="device", faults=faults,
                    metrics=MetricsSpec())
    res = run_fault_matrix(params, mlp_loss, mlp_accuracy, clients, te,
                           RandomScheme(p_bar=0.6, num_clients=K), h, cell,
                           cfg, rates=[0.0, 1.0])
    assert set(res.metrics) == {"guarded", "unguarded"}
    for name, ms in res.metrics.items():
        assert np.asarray(ms.tx_count).shape == (2, K)   # [rates, K]
        # the rate-0 lane is the clean world: every decision delivers
        np.testing.assert_array_equal(
            np.asarray(ms.tx_count)[0],
            np.asarray(res.delivered[name])[0].sum(axis=0))
    # the unguarded lanes carry no guard pipeline, hence no guard tap
    assert res.metrics["unguarded"].guard_events is None
    assert res.metrics["guarded"].guard_events is not None


# --- telemetry: manifests, spans, timed_compile -----------------------------


def test_manifest_emit_validate_jsonl_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    m = emit_run_manifest("test_kind", _cfg(), extra={"x": 1})
    assert validate_manifest(m) == []
    path = os.path.join(str(tmp_path), "runs.jsonl")
    assert os.path.exists(path)
    with open(path) as f:
        lines = [json.loads(l) for l in f if l.strip()]
    assert lines[-1]["kind"] == "test_kind"
    assert lines[-1]["extra"] == {"x": 1}
    assert validate_manifest(lines[-1]) == []
    assert obs_report.main(["--validate", path]) == 0
    assert obs_report.main(["--summary", path]) == 0
    # schema violations are caught
    assert validate_manifest({"kind": 1}) != []
    with open(path, "a") as f:
        f.write(json.dumps({"kind": "broken"}) + "\n")
    assert obs_report.main(["--validate", path]) == 1


def test_runners_emit_manifests():
    tel = get_telemetry()
    before = len(tel.manifests)
    _run_dense(_cfg(metrics=None))
    kinds = [m["kind"] for m in tel.manifests[before:]]
    assert "make_runner" in kinds
    for m in tel.manifests[before:]:
        assert validate_manifest(m) == []


def test_timed_compile_records_stage_spans():
    tel = get_telemetry()
    compiled = timed_compile(jax.jit(lambda x: (x * 2.0).sum()),
                             jnp.ones((8, 8)), label="obs_test")
    assert float(compiled(jnp.ones((8, 8)))) == 128.0
    assert tel.span_stats("obs_test.compile")["count"] >= 1
    assert (tel.span_stats("obs_test.lower") or
            tel.span_stats("obs_test.trace"))


# --- reporter: diff gate ----------------------------------------------------


def _write_json(path, payload):
    with open(path, "w") as f:
        json.dump(payload, f)
    return str(path)


def test_report_diff_gates_on_time_regressions(tmp_path):
    old = _write_json(tmp_path / "old.json",
                      {"dense": {"warm_s": 1.0, "count": 5},
                       "fingerprint": {"git_sha": "aaa"}})
    slow = _write_json(tmp_path / "slow.json",
                       {"dense": {"warm_s": 3.0, "count": 500},
                        "fingerprint": {"git_sha": "bbb"}})
    ok = _write_json(tmp_path / "ok.json",
                     {"dense": {"warm_s": 1.05, "count": 500},
                      "fingerprint": {"git_sha": "ccc"}})
    # 3x on a _s key: regression, exit 1; the non-time `count` never gates
    assert obs_report.main(["--diff", old, slow, "--threshold", "2.0"]) == 1
    assert obs_report.main(["--diff", old, ok, "--threshold", "2.0"]) == 0
    # threshold above the ratio: passes
    assert obs_report.main(["--diff", old, slow, "--threshold", "4.0"]) == 0
    d = obs_report.diff_benches(json.load(open(old)), json.load(open(slow)),
                                2.0)
    gated = {r["key"]: r["gated"] for r in d["rows"]}
    assert gated == {"dense.warm_s": True, "dense.count": False}
    assert [r["key"] for r in d["regressions"]] == ["dense.warm_s"]


# --- resumable driver: segment manifest + metrics threading -----------------


def test_resume_segment_manifest_roundtrip(tmp_path):
    clients, te, cell, h, params = tiny_world(K=K, rounds=T, dim=32)
    cfg = _cfg(checkpoint_every=3, metrics=MetricsSpec())
    pol = csma_policy(3, K)
    ckpt = str(tmp_path / "ckpt")
    # simulated kill after the first committed segment, then resume
    assert run_resumable(params, mlp_loss, mlp_accuracy, clients, te, pol,
                         h, cell, cfg, ckpt, stop_after_segment=1) is None
    assert len(read_segment_manifest(ckpt)) == 1
    res = run_resumable(params, mlp_loss, mlp_accuracy, clients, te, pol,
                        h, cell, cfg, ckpt)
    entries = read_segment_manifest(ckpt)
    n_segments = (T + 2) // 3
    assert [e["segment"] for e in entries] == list(range(n_segments))
    for e in entries:
        assert e["seed"] == cfg.seed and e["stride"] == 3
        assert e["t1"] > e["t0"] and e["wall_s"] > 0.0
        assert isinstance(e["config_sha"], str) and e["config_sha"]
        assert "backend" in e["fingerprint"]
    # metrics carry threads through checkpoints: the resumed run's taps
    # match an uninterrupted dense run bit-for-bit
    dense = _run_dense(cfg, policy=pol)
    assert res.metrics is not None
    assert_metrics_agree(res.metrics, dense.metrics, "resume-dense")
