"""Unit + property tests for the pure-JAX Lambert W (principal branch)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, settings, st

from repro.core.lambertw import INV_E, lambertw


def test_known_values():
    assert np.isclose(float(lambertw(jnp.array(0.0))), 0.0, atol=1e-7)
    assert np.isclose(float(lambertw(jnp.array(np.e))), 1.0, atol=1e-6)
    assert np.isclose(float(lambertw(jnp.array(-INV_E))), -1.0, atol=1e-6)
    # W(1) = Omega constant
    assert np.isclose(float(lambertw(jnp.array(1.0))), 0.5671432904, atol=1e-6)


def test_inverse_property_grid():
    # dense grid over the paper's operating range [-1/e, 0) plus positives
    x = np.concatenate([
        np.linspace(-INV_E + 1e-7, -1e-8, 301),
        np.linspace(1e-6, 50.0, 100),
    ]).astype(np.float32)
    w = np.asarray(lambertw(jnp.asarray(x)))
    err = np.abs(w * np.exp(w) - x)
    scale = np.maximum(np.abs(x), 1e-6)
    assert np.max(err / scale) < 1e-4


def test_nan_outside_domain():
    assert np.isnan(float(lambertw(jnp.array(-0.5))))


def test_branch_point_fp_noise_clamps_not_nan():
    """Callers build -exp(-A) in float32; rounding can land a few ulp below
    -1/e.  Within BRANCH_TOL the argument snaps to the branch point (W = -1)
    instead of poisoning the caller with NaN; genuinely out-of-domain
    arguments still return NaN."""
    from repro.core.lambertw import BRANCH_TOL

    for eps in (1e-9, 1e-8, 1e-7, BRANCH_TOL * 0.9):
        w = float(lambertw(jnp.float32(-INV_E - eps)))
        assert np.isclose(w, -1.0, atol=1e-6), (eps, w)
    assert np.isnan(float(lambertw(jnp.array(-INV_E - 1e-3))))


@settings(max_examples=60, deadline=None)
@given(st.floats(min_value=-INV_E + 1e-6, max_value=100.0,
                 allow_nan=False, allow_infinity=False))
def test_inverse_property_hypothesis(x):
    w = float(lambertw(jnp.float32(x)))
    assert np.isfinite(w)
    assert np.isclose(w * np.exp(w), x, rtol=2e-3, atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=1.0, max_value=40.0))
def test_paper_operating_branch(A):
    """The bandwidth formula evaluates W0(-exp(-A)) for A >= 1: result in [-1, 0)."""
    w = float(lambertw(jnp.float32(-np.exp(-A))))
    assert -1.0 <= w < 0.0
