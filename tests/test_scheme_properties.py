"""Property tests for the scheme layer: every policy emits probabilities in
[0, 1] and realizes {0,1} masks; every aggregator's weight program stays
finite, non-negative, and correctly normalized under arbitrary staleness,
delivery, and guard inputs.  Fuzzed via `hypothesis` when installed
(tests/_hypothesis_stub.py skips them cleanly otherwise); a deterministic
grid keeps the invariants exercised on clean environments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.core import CellConfig
from repro.core.selection import (age_aware_policy, age_policy, csma_policy,
                                  greedy_policy, policy_blend,
                                  policy_ledger_ok, random_policy)
from repro.fl.state import (AggregatorConfig, scheme_weights,
                            staleness_scale)

K = 7

POLICIES = {
    "random": random_policy(0.3, K),
    "greedy": greedy_policy(3, K),
    "age": age_policy(3, K),
    "csma": csma_policy(3, K),
    "csma-beta2": csma_policy(3, K, beta=2.0),
    "age-aware": age_aware_policy(3, K),
}

AGGS = [
    AggregatorConfig(kind="paper"),
    AggregatorConfig(kind="fedasync", staleness_fn="constant"),
    AggregatorConfig(kind="fedasync", staleness_fn="hinge"),
    AggregatorConfig(kind="fedasync", staleness_fn="poly"),
    AggregatorConfig(kind="csmaafl"),
    AggregatorConfig(kind="age"),
]


def _agg_id(a):
    return f"{a.kind}-{a.staleness_fn}"


def _gains(seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).gamma(2.0, scale, size=(K,)),
        jnp.float32)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,fn", POLICIES.items(), ids=POLICIES.keys())
@pytest.mark.parametrize("seed", [0, 3, 9])
def test_policy_probs_and_weights_valid(name, fn, seed):
    h = _gains(seed)
    probs, w = fn(jnp.int32(2), h, None)
    probs, w = np.asarray(probs), np.asarray(w)
    assert probs.shape == (K,) and w.shape == (K,)
    assert np.isfinite(probs).all() and np.isfinite(w).all()
    assert (probs >= 0).all() and (probs <= 1).all()
    assert (w >= 0).all() and w.sum() <= 1.0 + 1e-5


@pytest.mark.parametrize("name,fn", POLICIES.items(), ids=POLICIES.keys())
def test_policy_masks_are_binary(name, fn):
    from repro.fl.engine import apply_round_decision, SimConfig
    from repro.fl.state import init_fl_state
    cfg = SimConfig(rounds=4)
    cell = CellConfig(num_clients=K)
    st8 = init_fl_state({"w": jnp.zeros((3,))}, K)
    probs, w = fn(jnp.int32(1), _gains(4), st8)
    mask, forced, w2, e = apply_round_decision(
        probs, w, jnp.int32(1), _gains(4), st8, jax.random.PRNGKey(0), cfg,
        cell, K)
    m = np.asarray(mask)
    assert set(np.unique(m)) <= {0.0, 1.0}
    assert np.isfinite(np.asarray(e)).all() and (np.asarray(e) >= 0).all()
    # energy is charged exactly to the transmitting set
    assert ((np.asarray(e) > 0) <= (m > 0)).all()


def test_policy_blend_one_hot_is_exact():
    fns = [POLICIES["random"], POLICIES["csma"], POLICIES["age-aware"]]
    h = _gains(7)
    for i, fn in enumerate(fns):
        sel = jnp.zeros((len(fns),)).at[i].set(1.0)
        blended = policy_blend(fns, sel)
        p_ref, w_ref = fn(jnp.int32(3), h, None)
        p_bl, w_bl = blended(jnp.int32(3), h, None)
        np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_bl))
        np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_bl))
    assert policy_ledger_ok(policy_blend(fns, jnp.ones((3,)) / 3))


def test_ledger_tags():
    assert getattr(POLICIES["csma"], "state_free", False)
    assert not getattr(POLICIES["age-aware"], "state_free", False)
    assert policy_ledger_ok(POLICIES["age-aware"])
    blended_sf = policy_blend([POLICIES["random"], POLICIES["csma"]],
                              jnp.ones((2,)) / 2)
    assert getattr(blended_sf, "state_free", False)


# ---------------------------------------------------------------------------
# aggregation weights
# ---------------------------------------------------------------------------


def _check_weights(agg, mask, staleness, probs):
    ap = agg.params()
    a = np.asarray(scheme_weights(jnp.asarray(mask, jnp.float32),
                                  jnp.asarray(staleness, jnp.int32),
                                  jnp.asarray(probs, jnp.float32), ap, K))
    assert np.isfinite(a).all(), (agg.kind, a)
    assert (a >= 0).all(), (agg.kind, a)
    # weight only flows to delivered rows
    assert (a[np.asarray(mask) == 0] == 0).all()
    total = a.sum()
    m = np.asarray(mask, np.float64)
    if agg.kind == "paper":
        np.testing.assert_allclose(total, m.sum() / K, rtol=1e-5)
    elif m.sum() > 0:
        # normalized kinds: delivered weights sum to the mix coefficient
        np.testing.assert_allclose(total, agg.mix, rtol=1e-5)
    else:
        assert total == 0.0


@pytest.mark.parametrize("agg", AGGS, ids=_agg_id)
@pytest.mark.parametrize("case", ["all", "none", "one", "stale", "tiny-p"])
def test_weights_grid(agg, case):
    rng = np.random.default_rng(11)
    mask = {"all": np.ones(K), "none": np.zeros(K),
            "one": np.eye(K)[2], "stale": rng.integers(0, 2, K),
            "tiny-p": np.ones(K)}[case]
    staleness = {"stale": rng.integers(0, 200, K)}.get(
        case, rng.integers(0, 5, K))
    probs = (np.full(K, 1e-9) if case == "tiny-p"
             else rng.uniform(0.01, 1.0, K))
    _check_weights(agg, mask, staleness, probs)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_weights_fuzz(seed):
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 2, K).astype(np.float64)
    staleness = rng.integers(0, 10_000, K)
    probs = rng.uniform(0.0, 1.0, K)  # zeros exercise the prob_floor clamp
    for agg in AGGS:
        _check_weights(agg, mask, staleness, probs)


@given(s=st.integers(min_value=0, max_value=100_000))
@settings(max_examples=50, deadline=None)
def test_staleness_scale_bounded(s):
    for agg in AGGS:
        ap = agg.params()
        v = float(staleness_scale(jnp.full((1,), s, jnp.int32), ap)[0])
        assert np.isfinite(v) and 0.0 < v <= 1.0 + 1e-6


def test_staleness_scale_monotone_nonincreasing():
    ss = jnp.arange(0, 200, dtype=jnp.int32)
    for agg in AGGS:
        vals = np.asarray(staleness_scale(ss, agg.params()))
        assert (np.diff(vals) <= 1e-7).all(), agg.staleness_fn


def test_guarded_scheme_weights_stay_valid():
    # guards zero some rows; the normalized kinds renormalize over survivors
    from repro.fl.faults import GuardConfig
    from repro.fl.state import guard_weights, scheme_aggregate
    rng = np.random.default_rng(5)
    D = 4
    g = jnp.zeros((D,))
    deltas = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
    deltas = deltas.at[1].set(jnp.inf)  # quarantined row
    mask = jnp.ones((K,), jnp.float32)
    stale = jnp.asarray(rng.integers(0, 6, K), jnp.int32)
    probs = jnp.asarray(rng.uniform(0.1, 1.0, K), jnp.float32)
    out = scheme_aggregate(
        g, deltas, mask, K, stale, probs,
        AggregatorConfig(kind="fedasync", staleness_fn="poly"),
        guards=GuardConfig(quarantine=True))
    assert np.isfinite(np.asarray(out)).all()


def test_aggregator_config_validation():
    with pytest.raises(ValueError):
        AggregatorConfig(kind="nope")
    with pytest.raises(ValueError):
        AggregatorConfig(staleness_fn="nope")
