"""FL runtime tests: state algebra, protocol semantics, end-to-end learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig, ProblemSpec
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (AgeBasedScheme, GreedyScheme, ProposedOnline,
                                  RandomScheme)
from repro.data import make_mnist_like, shard_noniid
from repro.fl import SimConfig, init_fl_state, masked_aggregate, run_simulation
from repro.fl.state import broadcast_to_participants, pseudo_gradients
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss


def small_world(rounds=12, n_train=3000, K=10, d=5):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=n_train,
                             n_test=500)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=d)
    cell = CellConfig(num_clients=K)
    spec = ProblemSpec(cell=cell, rho=0.05, num_rounds=rounds)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4))
    return tr, te, clients, cell, spec, h, params


# --- state algebra ----------------------------------------------------------

def test_masked_aggregate_matches_eq3():
    params = {"w": jnp.zeros((3, 2))}
    deltas = {"w": jnp.stack([jnp.full((3, 2), float(k + 1))
                              for k in range(4)])}
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    out = masked_aggregate(params, deltas, mask, num_clients=4)
    # (1 + 3)/4 = 1.0
    assert np.allclose(np.asarray(out["w"]), 1.0)


def test_pseudo_gradient_is_difference():
    p = init_mlp(jax.random.PRNGKey(0), dims=(4, 3, 2))
    st = init_fl_state(p, num_clients=3)
    moved = jax.tree_util.tree_map(lambda x: x + 1.0, st.client_params)
    st = st._replace(client_params=moved)
    d = pseudo_gradients(st)
    for leaf in jax.tree_util.tree_leaves(d):
        assert np.allclose(np.asarray(leaf), 1.0)


def test_broadcast_only_to_participants():
    p = {"w": jnp.zeros((2,))}
    st = init_fl_state(p, num_clients=3)
    new_global = {"w": jnp.full((2,), 5.0)}
    mask = jnp.array([1.0, 0.0, 1.0])
    st2 = broadcast_to_participants(st, new_global, mask)
    cw = np.asarray(st2.client_params["w"])
    assert np.allclose(cw[0], 5.0) and np.allclose(cw[2], 5.0)
    assert np.allclose(cw[1], 0.0)           # non-participant keeps stale model
    assert np.asarray(st2.last_tx).tolist() == [0, 0, 0]  # tx at round index 0
    assert int(st2.round) == 1


def test_nonparticipants_keep_training_on_stale_anchor():
    """The async semantics of [13]: a client that never transmits still
    diverges from its (stale) anchor."""
    tr, te, clients, cell, spec, h, params = small_world(rounds=4,
                                                         n_train=1000)
    cfg = SimConfig(rounds=4, local_iters=2, batch_size=8, eval_every=10)

    class NeverClient0:
        name = "never0"

        def decide(self, t, h_t):
            probs = jnp.ones((10,)).at[0].set(0.0)
            return type("D", (), {"probs": probs,
                                  "w": jnp.full((10,), 0.1)})()

    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         NeverClient0(), h, cell, cfg)
    # client 0 never transmitted
    assert res.participation[:, 0].sum() == 0
    # its local model still moved away from its anchor (pseudo-gradient ≠ 0)
    d = pseudo_gradients(res.state)
    leaf = np.asarray(jax.tree_util.tree_leaves(d)[0])
    assert np.abs(leaf[0]).max() > 0.0


def test_learning_happens_and_energy_positive():
    tr, te, clients, cell, spec, h, params = small_world(rounds=15)
    cfg = SimConfig(rounds=15, local_iters=5, batch_size=10, eval_every=14)
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         ProposedOnline(spec), h, cell, cfg)
    assert res.test_acc[-1] > res.test_acc[0] + 0.05
    assert res.energy_per_client.sum() > 0
    assert np.all(np.diff(res.energy_timeline) >= -1e-9)


def test_max_staleness_enforced():
    tr, te, clients, cell, spec, h, params = small_world(rounds=10)
    cfg = SimConfig(rounds=10, local_iters=1, batch_size=8, eval_every=20,
                    max_staleness=2)
    res = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                         RandomScheme(p_bar=0.01, num_clients=10), h, cell, cfg)
    # with p̄≈0 every client is forced at least every 2 rounds
    gaps = []
    for k in range(10):
        tx = np.where(res.participation[:, k] > 0)[0]
        if len(tx) > 1:
            gaps.extend(np.diff(tx).tolist())
        assert len(tx) >= 4  # ~rounds/2 forced transmissions
    assert max(gaps) <= 2


def test_deterministic_schemes_select_k():
    g = GreedyScheme(k=3, num_clients=10)
    a = AgeBasedScheme(k=3, num_clients=10)
    h_t = jnp.logspace(-15, -12, 10)
    dg, da = g.decide(0, h_t), a.decide(0, h_t)
    assert float(dg.probs.sum()) == 3.0 and float(da.probs.sum()) == 3.0
    # greedy picks the 3 largest gains
    assert np.asarray(dg.probs)[-3:].tolist() == [1.0, 1.0, 1.0]
    # age-based cycles: rounds 0..3 cover all 10 clients with k=3
    seen = set()
    for t in range(4):
        seen.update(np.where(np.asarray(a.decide(t, h_t).probs) > 0)[0].tolist())
    assert len(seen) == 10


def test_masked_aggregate_pallas_path_matches_oracle():
    """The fused Pallas kernel (interpret mode on CPU) and the jnp oracle
    produce identical server updates over a real parameter pytree."""
    p = init_mlp(jax.random.PRNGKey(0), dims=(16, 8, 4))
    st = init_fl_state(p, num_clients=4)
    moved = jax.tree_util.tree_map(
        lambda x: x + jax.random.normal(jax.random.PRNGKey(1), x.shape) * 0.1,
        st.client_params)
    st = st._replace(client_params=moved)
    d = pseudo_gradients(st)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    ref = masked_aggregate(st.global_params, d, mask, 4)
    fused = masked_aggregate(st.global_params, d, mask, 4, use_pallas=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_aging_boost_reduces_max_gap_without_forcing():
    """Soft aging (beyond-paper): probability rises with staleness, so max
    transmission gaps shrink vs pure Bernoulli at low p̄."""
    tr, te, clients, cell, spec, h, params = small_world(rounds=16)
    base = SimConfig(rounds=16, local_iters=1, batch_size=8, eval_every=20,
                     max_staleness=4)
    aged = SimConfig(rounds=16, local_iters=1, batch_size=8, eval_every=20,
                     max_staleness=4, aging_boost=True)
    pol = RandomScheme(p_bar=0.02, num_clients=10)
    r_aged = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                            pol, h, cell, aged)
    # every client transmits at least every 4 rounds
    for k in range(10):
        tx = np.where(r_aged.participation[:, k] > 0)[0]
        assert len(tx) >= 3
        if len(tx) > 1:
            assert np.diff(tx).max() <= 4
    # aging transmits *more* than the un-boosted baseline on average
    r_base = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                            pol, h, cell, base)
    assert r_aged.participation.sum() >= r_base.participation.sum() - 1
