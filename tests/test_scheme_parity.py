"""Three-way sparse↔dense↔legacy parity for every pluggable aggregator,
plus the scheme-matrix fan-out: dense and sparse matrices agree lane for
lane, each path compiles once, and `aggregator=None` stays byte-identical
to the pre-scheme engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CellConfig
from repro.core.channel import channel_gains, sample_positions
from repro.core.selection import (age_aware_policy, csma_policy,
                                  random_policy)
from repro.data import Dataset, make_mnist_like, shard_noniid
from repro.data.device import from_client_datasets
from repro.fl import (AggregatorConfig, SimConfig, make_sparse_runner,
                      run_simulation, run_simulation_legacy)
from repro.fl import sparse as sparse_mod
from repro.fl.schemes import (SchemeSpec, default_scheme_panel,
                              run_scheme_matrix, stack_stores)
from repro.models.small import init_mlp, mlp_accuracy, mlp_loss

K, T, DIM = 5, 8, 32

AGGREGATORS = [
    AggregatorConfig(kind="paper"),
    AggregatorConfig(kind="fedasync", staleness_fn="constant"),
    AggregatorConfig(kind="fedasync", staleness_fn="hinge"),
    AggregatorConfig(kind="fedasync", staleness_fn="poly"),
    AggregatorConfig(kind="csmaafl"),
    AggregatorConfig(kind="age"),
]


def _agg_id(agg):
    return f"{agg.kind}-{agg.staleness_fn}"


def tiny_world(K=K, rounds=T, dim=DIM, d=2):
    tr, te = make_mnist_like(jax.random.PRNGKey(0), n_train=800, n_test=200)
    clients = shard_noniid(jax.random.PRNGKey(1), tr, K, d=d)
    clients = [Dataset(c.x[:, :dim], c.y, c.num_classes) for c in clients]
    te = Dataset(te.x[:, :dim], te.y, te.num_classes)
    cell = CellConfig(num_clients=K)
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h = channel_gains(jax.random.PRNGKey(3), pos, rounds).T
    params = init_mlp(jax.random.PRNGKey(4), dims=(dim, 16, 10))
    return clients, te, cell, h, params


def sparse_cfg(**kw):
    base = dict(rounds=T, local_iters=2, batch_size=4, eval_every=2,
                local_mode="participants", data_path="device",
                data_stream="client")
    base.update(kw)
    return SimConfig(**base)


def three_way(cfg, policy):
    clients, te, cell, h, params = tiny_world()
    scan = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                          policy, h, cell, cfg)
    legacy = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients,
                                   te, policy, h, cell, cfg)
    sp = make_sparse_runner(mlp_loss, mlp_accuracy, clients, te, policy,
                           cell, cfg)(params, h)
    return scan, legacy, sp


def assert_three_way(scan, legacy, sp):
    # identical fold_in streams ⇒ identical realized masks on all paths
    np.testing.assert_array_equal(scan.participation, legacy.participation)
    np.testing.assert_array_equal(scan.participation, sp.participation)
    np.testing.assert_array_equal(scan.eval_rounds, legacy.eval_rounds)
    np.testing.assert_array_equal(scan.eval_rounds, sp.eval_rounds)
    for other in (legacy, sp):
        np.testing.assert_allclose(scan.energy_per_client,
                                   other.energy_per_client, rtol=1e-6)
        np.testing.assert_allclose(scan.energy_timeline,
                                   other.energy_timeline, rtol=1e-5)
        np.testing.assert_allclose(scan.test_acc, other.test_acc, atol=1e-5)
        np.testing.assert_allclose(scan.test_loss, other.test_loss,
                                   atol=1e-5)


@pytest.mark.parametrize("agg", AGGREGATORS, ids=_agg_id)
def test_aggregator_three_way_parity(agg):
    scan, legacy, sp = three_way(sparse_cfg(aggregator=agg),
                                 csma_policy(3, K))
    assert_three_way(scan, legacy, sp)


@pytest.mark.parametrize("agg", [AGGREGATORS[0], AGGREGATORS[3],
                                 AGGREGATORS[5]], ids=_agg_id)
def test_ledger_policy_three_way_parity(agg):
    # age-aware scheduling reads only (round, last_tx): phase A carries it
    scan, legacy, sp = three_way(sparse_cfg(aggregator=agg),
                                 age_aware_policy(2, K))
    assert_three_way(scan, legacy, sp)


def test_aggregator_none_is_bitwise_legacy_program():
    # the None default must keep the exact pre-scheme program: the paper
    # kind through the weighted path is numerically equal but need not be
    # bit-identical (different float reduction order), so None is the
    # bit-parity anchor
    cfg_none = sparse_cfg(aggregator=None)
    clients, te, cell, h, params = tiny_world()
    a = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                       csma_policy(3, K), h, cell, cfg_none)
    b = run_simulation_legacy(params, mlp_loss, mlp_accuracy, clients, te,
                              csma_policy(3, K), h, cell, cfg_none)
    np.testing.assert_array_equal(a.participation, b.participation)
    np.testing.assert_array_equal(np.asarray(a.test_loss),
                                  np.asarray(b.test_loss))


def test_paper_kind_matches_plain_average():
    # kind="paper" realizes the same m/K weights as masked_aggregate
    cfg_plain = sparse_cfg(aggregator=None)
    cfg_paper = sparse_cfg(aggregator=AggregatorConfig(kind="paper"))
    clients, te, cell, h, params = tiny_world()
    plain = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                           csma_policy(3, K), h, cell, cfg_plain)
    paper = run_simulation(params, mlp_loss, mlp_accuracy, clients, te,
                           csma_policy(3, K), h, cell, cfg_paper)
    np.testing.assert_array_equal(plain.participation, paper.participation)
    np.testing.assert_allclose(plain.test_loss, paper.test_loss, atol=1e-5)
    np.testing.assert_allclose(plain.energy_per_client,
                               paper.energy_per_client, rtol=1e-6)


def test_guards_compose_with_scheme_aggregation():
    from repro.fl import GuardConfig
    agg = AggregatorConfig(kind="fedasync", staleness_fn="poly")
    guards = GuardConfig(clip_norm=0.05)
    scan, legacy, sp = three_way(sparse_cfg(aggregator=agg, guards=guards),
                                 csma_policy(3, K))
    assert_three_way(scan, legacy, sp)


def test_schemes_differ():
    # the panel is a real comparison: different aggregators produce
    # different trajectories on the same channel/PRNG realization
    cfg = sparse_cfg
    clients, te, cell, h, params = tiny_world()
    pol = csma_policy(3, K)
    losses = {}
    for agg in (AggregatorConfig(kind="fedasync", staleness_fn="poly"),
                AggregatorConfig(kind="csmaafl"),
                AggregatorConfig(kind="age")):
        r = run_simulation(params, mlp_loss, mlp_accuracy, clients, te, pol,
                           h, cell, cfg(aggregator=agg))
        losses[agg.kind] = np.asarray(r.test_loss)
    assert not np.allclose(losses["fedasync"], losses["csmaafl"])
    assert not np.allclose(losses["fedasync"], losses["age"])


# ---------------------------------------------------------------------------
# scheme matrix fan-out
# ---------------------------------------------------------------------------


def _matrix_world(S=2, V=2):
    _, te, cell, _, params = tiny_world()
    tr, _ = make_mnist_like(jax.random.PRNGKey(0), n_train=800, n_test=200)
    severities, stores = [], []
    for d in (2, 4)[:V]:
        cs = shard_noniid(jax.random.PRNGKey(1), tr, K, d=d)
        cs = [Dataset(c.x[:, :DIM], c.y, c.num_classes) for c in cs]
        severities.append(cs)
        stores.append(from_client_datasets(cs, pad_to=256))
    pos = sample_positions(jax.random.PRNGKey(2), cell)
    h_stack = jnp.stack([channel_gains(jax.random.PRNGKey(30 + s), pos, T).T
                         for s in range(S)])
    return severities, stores, te, cell, h_stack, params


def _panel():
    return [
        SchemeSpec("paper", random_policy(0.4, K),
                   AggregatorConfig(kind="paper")),
        SchemeSpec("fedasync", random_policy(0.4, K),
                   AggregatorConfig(kind="fedasync", staleness_fn="poly")),
        SchemeSpec("csmaafl", csma_policy(3, K),
                   AggregatorConfig(kind="csmaafl")),
        SchemeSpec("age-aware", age_aware_policy(2, K),
                   AggregatorConfig(kind="age")),
    ]


def test_scheme_matrix_dense_sparse_agree():
    _, stores, te, cell, h_stack, params = _matrix_world()
    cfg = sparse_cfg()
    seeds = [0, 1]
    dense = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                              _panel(), h_stack, cell, cfg, seeds,
                              participation="dense")
    sparse = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                               _panel(), h_stack, cell, cfg, seeds,
                               participation="sparse")
    assert dense.acc.shape == (2, 4, 2, dense.eval_rounds.size)
    np.testing.assert_array_equal(dense.participation, sparse.participation)
    np.testing.assert_allclose(dense.energy, sparse.energy, rtol=1e-6)
    np.testing.assert_allclose(dense.loss, sparse.loss, atol=1e-5)
    np.testing.assert_allclose(dense.energy_timeline,
                               sparse.energy_timeline, rtol=1e-5)


def test_scheme_matrix_lanes_match_single_runs():
    # lane (v, l, s) of the matrix == a single dense run with that scheme,
    # that severity, that seed — the one-hot blend is exact
    severities, stores, te, cell, h_stack, params = _matrix_world()
    cfg = sparse_cfg()
    panel = _panel()
    mat = run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te,
                            panel, h_stack, cell, cfg, seeds=[0, 1],
                            participation="dense")
    from repro.fl.engine import make_runner
    import dataclasses
    for (v, l, s) in [(0, 0, 0), (1, 2, 1), (0, 3, 1)]:
        cfg_l = dataclasses.replace(cfg, aggregator=panel[l].aggregator)
        runner = make_runner(mlp_loss, mlp_accuracy, severities[v], te,
                             panel[l].policy, cell, cfg_l)
        single = runner(params, h_stack[s], seed=s)
        np.testing.assert_array_equal(mat.participation[v, l, s],
                                      single.participation)
        np.testing.assert_allclose(mat.loss[v, l, s], single.test_loss,
                                   atol=1e-5)
        np.testing.assert_allclose(mat.energy[v, l, s],
                                   single.energy_per_client, rtol=1e-6)


def test_scheme_matrix_sparse_single_train_trace():
    # the sparse matrix is one vmapped device program: the bucket-shaped
    # training program traces exactly once for the whole fan-out
    _, stores, te, cell, h_stack, params = _matrix_world()
    cfg = sparse_cfg()
    before = sparse_mod.TRAIN_TRACE_COUNT
    run_scheme_matrix(params, mlp_loss, mlp_accuracy, stores, te, _panel(),
                      h_stack, cell, cfg, seeds=[0, 1],
                      participation="sparse")
    assert sparse_mod.TRAIN_TRACE_COUNT == before + 1


def test_default_scheme_panel_shape():
    from repro.core import ProblemSpec
    spec = ProblemSpec(cell=CellConfig(num_clients=K), rho=0.05,
                       num_rounds=T)
    panel = default_scheme_panel(spec, K, rhos=(0.5, 2.0))
    names = [s.name for s in panel]
    assert len(panel) >= 5 and len(set(names)) == len(names)
    kinds = {s.aggregator.kind for s in panel}
    assert {"paper", "fedasync", "csmaafl", "age"} <= kinds


def test_stack_stores_rejects_mismatched_shapes():
    clients, *_ = tiny_world()
    a = from_client_datasets(clients, pad_to=256)
    b = from_client_datasets(clients, pad_to=512)
    with pytest.raises(ValueError, match="pad_to"):
        stack_stores([a, b])
